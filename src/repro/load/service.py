"""Service models — what one admitted request costs the cluster.

The open-loop harness (:mod:`repro.load.harness`) needs exactly one
number per tenant: the seconds a request of that tenant occupies a
service lane.  Two providers:

* :class:`FixedServiceModel` — a literal table.  The unit-test and
  property-test workhorse: queueing invariants (conservation, FIFO,
  fairness, determinism) are independent of where service times come
  from.
* :class:`PlanServiceModel` — the production path: service times are the
  planner's own ``predicted_latency``, resolved through the shared
  multi-tenant :class:`~repro.serving.plan_cache.PlanCache`.  Because the
  cache is membership-keyed, *churn re-prices service*: when a
  ``FleetController`` epoch changes the availability mask, the next
  resolution per tenant is that tenant's single frontier pass for the new
  membership (a warm hit for a returning one) — the
  one-frontier-pass-per-tenant-per-epoch invariant, counter-verified via
  ``PlanCache.stats()``.  The harness calls :meth:`begin_epoch` at each
  membership epoch; between epochs every ``service_time`` call is a local
  memo read, so a 10⁵-request run prices requests in O(tenants × epochs)
  planner work, not O(requests).

Planner overhead never enters the open-loop timeline: the cache amortizes
it to microseconds (tab1 measures it), and charging wall-clock would
break the seeded-replay byte-identity the telemetry contract gates.
"""

from __future__ import annotations

from typing import Mapping


class FixedServiceModel:
    """A fixed tenant → service-seconds table."""

    def __init__(self, times: Mapping[str, float]):
        for name, s in times.items():
            if s <= 0:
                raise ValueError(f"service time for {name!r} must be "
                                 f"positive, got {s}")
        self.times = dict(times)

    def begin_epoch(self, epoch: int | None = None) -> None:
        """Membership epochs do not re-price a fixed table."""

    def service_time(self, tenant: str) -> float:
        return self.times[tenant]

    def __repr__(self) -> str:
        return f"FixedServiceModel({self.times})"


class PlanServiceModel:
    """Service times resolved from the (membership-keyed) plan cache.

    ``specs`` maps tenant name → an object with ``dag`` (the tenant's
    ModelDAG), ``delta`` (compute intensity) and optionally ``objective``
    — a :class:`~repro.load.harness.TenantSpec` fits.  Resolutions are
    memoized until :meth:`begin_epoch` clears the memo, so the cache (and
    its hit/miss counters) sees exactly one ``get`` per tenant per epoch.

    Attributes:
        cache: the :class:`~repro.serving.plan_cache.PlanCache` resolved
            through (wire its ``membership_source`` to the same
            ``FleetController`` the harness advances).
        resolutions: lifetime ``cache.get`` calls — O(tenants × epochs),
            never O(requests).
    """

    def __init__(self, cache, specs: Mapping[str, object]):
        for name, spec in specs.items():
            if getattr(spec, "dag", None) is None:
                raise ValueError(
                    f"tenant {name!r} has no dag: PlanServiceModel prices "
                    "tenants by planning them — give TenantSpec a dag, or "
                    "use FixedServiceModel")
        self.cache = cache
        self.specs = dict(specs)
        self.resolutions = 0
        self._memo: dict[str, float] = {}

    def begin_epoch(self, epoch: int | None = None) -> None:
        """The membership moved: forget memoized prices so each tenant's
        next ``service_time`` re-resolves against the new mask (one
        cache ``get`` per tenant — a frontier pass only if this
        membership was never planned before)."""
        self._memo.clear()

    def service_time(self, tenant: str) -> float:
        s = self._memo.get(tenant)
        if s is None:
            spec = self.specs[tenant]
            plan = self.cache.get(
                spec.dag, objective=getattr(spec, "objective", None),
                delta=getattr(spec, "delta", None))
            self.resolutions += 1
            s = float(plan.predicted_latency)
            if s <= 0:
                raise ValueError(f"plan for tenant {tenant!r} predicts "
                                 f"non-positive latency {s}")
            self._memo[tenant] = s
        return s

    def __repr__(self) -> str:
        return (f"PlanServiceModel({len(self.specs)} tenants, "
                f"{self.resolutions} resolutions)")
