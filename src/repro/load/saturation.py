"""Saturation sweeps — the open-loop variants of fig7/fig8.

A sweep replays *one* seeded arrival trace at a ladder of offered-load
factors (:meth:`~repro.load.traces.ArrivalTrace.scaled` — time
compression, so every load level sees the identical arrival sequence) and
runs each through the queueing harness.  The resulting curve is the
classic open-loop saturation story:

* below the knee — throughput tracks offered load, p99 flat, no sheds;
* at the knee (offered ≈ :func:`mix_capacity`) — queues build, p99 lifts;
* above it — throughput plateaus at capacity, and with admission control
  + shedding the *excess* shows up as rejects/sheds while the traffic
  that is served keeps meeting its SLO.

``benchmarks/fig9_saturation.py`` draws these curves (with and without a
composed churn trace) and exit-code-gates the shape; docs/load.md walks
through reading them.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping, Sequence

from .harness import LoadConfig, LoadReport, OpenLoopHarness, TenantSpec
from .traces import ArrivalTrace


@dataclasses.dataclass(frozen=True)
class SaturationPoint:
    """One offered-load level of a sweep.

    Attributes:
        factor: the time-compression factor applied to the base trace.
        offered: offered arrivals/second at this level.
        report: the full per-request :class:`LoadReport`.
    """

    factor: float
    offered: float
    report: LoadReport

    @property
    def throughput(self) -> float:
        return self.report.throughput()

    @property
    def p50(self) -> float:
        return self.report.percentile(50)

    @property
    def p99(self) -> float:
        return self.report.percentile(99)

    @property
    def goodput(self) -> float:
        """Completions *within SLO* per second over the horizon."""
        r = self.report
        h = max(r.trace.horizon, 1e-12)
        return (r.completed - r.slo_violations()) / h

    @property
    def loss_rate(self) -> float:
        """Fraction of arrivals turned away (rejected or shed)."""
        r = self.report
        return (r.rejected + r.shed) / max(r.arrived, 1)

    def row(self) -> dict[str, float]:
        """A flat dict for tables / telemetry gauges."""
        r = self.report
        return {
            "factor": self.factor,
            "offered": self.offered,
            "throughput": self.throughput,
            "goodput": self.goodput,
            "p50": self.p50,
            "p99": self.p99,
            "arrived": float(r.arrived),
            "completed": float(r.completed),
            "rejected": float(r.rejected),
            "shed": float(r.shed),
            "slo_violation_rate": (0.0 if r.completed == 0
                                   else r.slo_violation_rate()),
            "loss_rate": self.loss_rate,
        }


def mix_capacity(service_times: Mapping[str, float],
                 rates: Mapping[str, float], *, servers: int = 1) -> float:
    """The cluster's saturation throughput (requests/second) for a tenant
    mix: with mean service time ``s̄ = Σ pᵢ·sᵢ`` under the mix's arrival
    proportions ``pᵢ``, ``servers / s̄``.  The anchor for the no-shedding
    plateau; when shedding biases the *served* mix toward cheap tenants,
    throughput in requests/s can legitimately sit above this line — gate
    on :meth:`LoadReport.utilization` (≤ 1 always) in that regime."""
    total = sum(rates.values())
    if total <= 0:
        return math.inf
    mean = sum(service_times[n] * (r / total) for n, r in rates.items())
    return servers / mean if mean > 0 else math.inf


def saturation_sweep(trace: ArrivalTrace,
                     specs: Mapping[str, TenantSpec] | Sequence[TenantSpec],
                     service_model,
                     factors: Sequence[float],
                     config: LoadConfig = LoadConfig(), *,
                     fleet_factory: Callable[[], object] | None = None,
                     telemetry=None) -> list[SaturationPoint]:
    """Run ``trace.scaled(f)`` through the harness for each factor.

    ``fleet_factory`` (not a shared instance — a ``FleetController`` is
    stateful and each load level must replay churn from epoch 0) builds a
    fresh fleet per level; None sweeps a static cluster.  Points come
    back in ``factors`` order.
    """
    points = []
    for f in factors:
        scaled = trace.scaled(f)
        fleet = fleet_factory() if fleet_factory is not None else None
        harness = OpenLoopHarness(scaled, specs, service_model, config,
                                  fleet=fleet, telemetry=telemetry)
        report = harness.run()
        points.append(SaturationPoint(factor=float(f),
                                      offered=scaled.offered_rate(),
                                      report=report))
    return points
