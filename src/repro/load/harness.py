"""Open-loop queueing harness — arrivals meet a finite cluster.

The harness replays an :class:`~repro.load.traces.ArrivalTrace` against a
cluster abstracted as ``servers`` identical service lanes priced by a
service model (:mod:`repro.load.service`).  It is an *event-driven* loop
over two event kinds (arrival, lane-free) with all per-request state in
preallocated numpy arrays — 10⁵–10⁶ requests per run; the planner is
consulted O(tenants × epochs) times, never per request.

Request lifecycle (every arrival ends in exactly one terminal state)::

    arrive ──(queue full)──────────────▶ REJECTED    admission control
      │
      ▼ enqueue (per-tenant FIFO)
    queued ──(stale / doomed at dispatch)──▶ SHED    backpressure
      │
      ▼ dispatch (priority → WDRR)                   "admitted"
    in service ────────────────────────▶ COMPLETED

* **Admission control** — ``queue_capacity`` bounds the total backlog;
  an arrival that finds the queue full is rejected on the spot.  Bounded
  queues are what turn overload into accounted-for rejects instead of
  unbounded latency.
* **SLO-aware priorities** — tenants are grouped into priority classes
  (explicit ``TenantSpec.priority``, or derived: tighter SLO → served
  first).  Classes are strict and non-preemptive: a lane never takes a
  looser-class request while a tighter-class one is queued.
* **Per-tenant fairness** — within a class, weighted deficit round-robin
  (DRR): each visit credits a tenant ``quantum × weight`` seconds of
  service and serves while the head is affordable, so over any backlogged
  interval tenants receive service seconds proportional to their weights
  (within one quantum), regardless of who floods the queue.
* **Backpressure / shedding** — at dispatch, a request that waited past
  ``max_wait``, or whose SLO can no longer be met even if served
  immediately (``shed_doomed``), is shed rather than served.  Under
  sustained overload the queue stays bounded, sheds/rejects grow, and the
  *served* traffic keeps meeting its SLO — the saturation gate.
* **Churn** — pass ``fleet=`` (a ``repro.fleet.FleetController``): the
  trace's availability events are consumed as simulated time advances,
  and every membership epoch re-prices service via
  ``service_model.begin_epoch`` (with a
  :class:`~repro.load.service.PlanServiceModel`, one membership-keyed
  cache resolution per tenant per epoch).
* **Telemetry** — every queue decision is recorded: ``load.reject`` /
  ``load.shed`` / ``load.admit`` counters, ``load.queue_wait`` and
  ``load.service`` spans per dispatch and a ``load.request`` span per
  completion, all epoch-stamped with deterministic domain time.  The
  event loop cannot nest ``trace()`` contexts (a request's life spans
  many loop iterations), so each enqueued arrival gets an explicitly
  allocated span id: queue-wait/service/shed events carry it as
  ``parent_id`` and the terminal ``load.request`` claims it as
  ``span_id`` — the flat log still reconstructs into per-request trees
  (:mod:`repro.telemetry.trace`), and two seeded replays of the same
  trace produce byte-identical canonical logs (docs/observability.md).

Ties are deterministic: a lane-free event at the same instant as an
arrival is processed first (the freed slot is visible to the arrival's
admission check), and simultaneous arrivals dispatch in trace order.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from typing import Mapping, Sequence

import numpy as np

from .traces import ArrivalTrace

# request terminal/transient states (LoadReport.status values)
QUEUED, IN_FLIGHT, COMPLETED, REJECTED, SHED = 0, 1, 2, 3, 4
STATUS_NAMES = ("queued", "in_flight", "completed", "rejected", "shed")


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's serving contract.

    Attributes:
        name: the tenant's name in the arrival trace.
        slo: end-to-end latency objective in seconds (None = best-effort).
        weight: WDRR share within the tenant's priority class.
        priority: explicit class (lower = served first); None derives it
            from the SLO — tighter SLOs get tighter classes, best-effort
            tenants the loosest.
        dag: the tenant's ModelDAG (what a ``PlanServiceModel`` prices).
        delta: compute intensity — part of the tenant's plan-cache key.
        objective: planning objective name for plan resolution (None =
            the planner's default, latency).
    """

    name: str
    slo: float | None = None
    weight: float = 1.0
    priority: int | None = None
    dag: object | None = None
    delta: float | None = None
    objective: str | None = None

    def __post_init__(self) -> None:
        if self.weight <= 0:
            raise ValueError("weight must be positive")
        if self.slo is not None and self.slo <= 0:
            raise ValueError("slo must be positive seconds")


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    """Queueing knobs.

    Attributes:
        servers: concurrent service lanes the cluster sustains (HiDP's
            data-parallel plans span the whole cluster, so 1 is the
            faithful default; pipelined executors raise it).
        queue_capacity: max queued (not yet dispatched) requests across
            all tenants; an arrival over the cap is rejected.  None =
            unbounded (no admission control).
        max_wait: shed any request that waited longer than this at
            dispatch time (None = no age limit).
        shed_doomed: shed a request whose SLO is already unmeetable at
            dispatch (``wait + service > slo``) — serving it would burn
            capacity on a guaranteed violation.
        quantum: WDRR credit in service-seconds per unit weight per
            round; None auto-sizes to the largest current service time
            (the classic DRR choice — every backlogged tenant can afford
            its head once per round).
        drain: after the last arrival, keep serving until the queue is
            empty (True, the default) or stop the clock at the last
            arrival and leave the backlog as ``queued``/``in_flight``.
    """

    servers: int = 1
    queue_capacity: int | None = None
    max_wait: float | None = None
    shed_doomed: bool = True
    quantum: float | None = None
    drain: bool = True

    def __post_init__(self) -> None:
        if self.servers < 1:
            raise ValueError("servers must be >= 1")
        if self.queue_capacity is not None and self.queue_capacity < 0:
            raise ValueError("queue_capacity must be >= 0")
        if self.max_wait is not None and self.max_wait <= 0:
            raise ValueError("max_wait must be positive")
        if self.quantum is not None and self.quantum <= 0:
            raise ValueError("quantum must be positive")


def derive_priorities(specs: Sequence[TenantSpec]) -> dict[str, int]:
    """Effective priority class per tenant: explicit ``priority`` wins;
    otherwise classes are ranked by SLO tightness (distinct SLOs
    ascending → class 0, 1, …) with best-effort (no-SLO) tenants in the
    loosest derived class."""
    slos = sorted({s.slo for s in specs
                   if s.priority is None and s.slo is not None})
    rank = {slo: i for i, slo in enumerate(slos)}
    out = {}
    for s in specs:
        if s.priority is not None:
            out[s.name] = int(s.priority)
        elif s.slo is not None:
            out[s.name] = rank[s.slo]
        else:
            out[s.name] = len(rank)
    return out


@dataclasses.dataclass
class LoadReport:
    """Per-request outcome arrays plus the aggregates the saturation
    curves are drawn from.  ``status[i]`` is the i-th *arrival*'s fate
    (trace order); ``start``/``finish`` are NaN for requests that never
    dispatched/completed."""

    trace: ArrivalTrace
    specs: tuple[TenantSpec, ...]
    config: LoadConfig
    status: np.ndarray          # (N,) int8
    start: np.ndarray           # (N,) float64, dispatch instant
    finish: np.ndarray          # (N,) float64, completion instant
    clock_end: float            # when the run stopped

    # ------------------------------------------------------------- counts
    def count(self, status: int) -> int:
        return int(np.count_nonzero(self.status == status))

    @property
    def arrived(self) -> int:
        return int(self.status.size)

    @property
    def completed(self) -> int:
        return self.count(COMPLETED)

    @property
    def rejected(self) -> int:
        return self.count(REJECTED)

    @property
    def shed(self) -> int:
        return self.count(SHED)

    @property
    def in_flight(self) -> int:
        return self.count(IN_FLIGHT)

    @property
    def queued(self) -> int:
        return self.count(QUEUED)

    @property
    def admitted(self) -> int:
        """Requests that entered service: completed + still in flight."""
        return self.completed + self.in_flight

    def conservation_ok(self) -> bool:
        """arrived = admitted + rejected + shed + still-queued, and
        admitted = completed + in-flight — every arrival has exactly one
        fate."""
        return (self.arrived == self.admitted + self.rejected + self.shed
                + self.queued)

    # ---------------------------------------------------------- latencies
    def _done(self) -> np.ndarray:
        return self.status == COMPLETED

    def latencies(self) -> np.ndarray:
        """End-to-end (queue wait + service) seconds of completed
        requests, trace order."""
        m = self._done()
        return (self.finish[m] - self.trace.times[m])

    def waits(self) -> np.ndarray:
        """Queue-wait seconds of every dispatched request."""
        m = ~np.isnan(self.start)
        return self.start[m] - self.trace.times[m]

    def percentile(self, q: float) -> float:
        lat = self.latencies()
        return float(np.percentile(lat, q)) if lat.size else math.nan

    def slo_violations(self) -> int:
        """Completed requests that finished past their tenant's SLO."""
        slos = np.array([math.inf if s.slo is None else s.slo
                         for s in self.specs])
        m = self._done()
        lat = self.finish[m] - self.trace.times[m]
        return int(np.count_nonzero(lat > slos[self.trace.tenant_ids[m]]))

    def slo_violation_rate(self) -> float:
        """Violations among *served* requests — what admission control and
        doomed-shedding protect.  NaN when nothing completed."""
        done = self.completed
        return self.slo_violations() / done if done else math.nan

    def utilization(self, horizon: float | None = None) -> float:
        """Delivered service-seconds per lane-second over ``[0, horizon)``
        (default: until the clock stopped).  Physically bounded by 1.0 —
        the saturation gate's hard ceiling: no scheduler can deliver more
        service than the lanes hold.  (Throughput can legitimately exceed
        the *offered-mix* capacity when shedding biases the served mix
        toward cheap tenants, so gate on utilization, not requests/s.)"""
        horizon = self.clock_end if horizon is None else horizon
        if horizon <= 0:
            return 0.0
        s = np.minimum(self.finish, horizon) - np.minimum(self.start,
                                                          horizon)
        busy = float(np.nansum(np.clip(s, 0.0, None)))
        return busy / (self.config.servers * horizon)

    def throughput(self, horizon: float | None = None) -> float:
        """Completions per second inside ``[0, horizon)`` (default: the
        trace horizon) — the saturation curve's y-axis."""
        horizon = self.trace.horizon if horizon is None else horizon
        m = self._done() & (self.finish <= horizon)
        return float(np.count_nonzero(m)) / max(horizon, 1e-12)

    # ----------------------------------------------------------- breakdown
    def per_tenant(self) -> dict[str, dict[str, float]]:
        out: dict[str, dict[str, float]] = {}
        ids = self.trace.tenant_ids
        for ti, spec in enumerate(self.specs):
            m = ids == ti
            st = self.status[m]
            done = (st == COMPLETED)
            lat = (self.finish[m] - self.trace.times[m])[done]
            viol = (int(np.count_nonzero(lat > spec.slo))
                    if spec.slo is not None else 0)
            out[spec.name] = {
                "arrived": int(st.size),
                "completed": int(np.count_nonzero(done)),
                "rejected": int(np.count_nonzero(st == REJECTED)),
                "shed": int(np.count_nonzero(st == SHED)),
                "p50": float(np.percentile(lat, 50)) if lat.size
                else math.nan,
                "p99": float(np.percentile(lat, 99)) if lat.size
                else math.nan,
                "slo_violations": viol,
                "service_seconds": float(np.nansum(
                    (self.finish[m] - self.start[m])[done])),
            }
        return out

    def __repr__(self) -> str:
        return (f"LoadReport({self.arrived} arrived: {self.completed} "
                f"completed, {self.rejected} rejected, {self.shed} shed, "
                f"p99={self.percentile(99):.3g}s)")


class _DRRClass:
    """One priority class's weighted deficit round-robin state."""

    __slots__ = ("tenants", "ptr", "fresh")

    def __init__(self, tenants: list[int]):
        self.tenants = tenants
        self.ptr = 0
        self.fresh = True


class OpenLoopHarness:
    """Replays one arrival trace through the queueing layer.

    Attributes:
        trace / specs / config: the run's inputs (specs may omit tenants
            only if the trace has none of their arrivals — every trace
            tenant needs a spec).
        service_model: tenant → service-seconds provider
            (:mod:`repro.load.service`).
        fleet: optional ``repro.fleet.FleetController`` — availability
            events are consumed as simulated time passes; each epoch
            re-prices service.
        telemetry: optional ``repro.telemetry.TelemetryRecorder``.
        epochs_seen: membership epochs observed mid-run.
    """

    def __init__(self, trace: ArrivalTrace,
                 specs: Mapping[str, TenantSpec] | Sequence[TenantSpec],
                 service_model, config: LoadConfig = LoadConfig(), *,
                 fleet=None, telemetry=None):
        if not isinstance(specs, Mapping):
            specs = {s.name: s for s in specs}
        missing = [n for n in trace.tenants if n not in specs]
        if missing:
            raise ValueError(f"no TenantSpec for trace tenants {missing}")
        self.trace = trace
        self.specs = tuple(specs[n] for n in trace.tenants)
        self.config = config
        self.service_model = service_model
        self.fleet = fleet
        from repro.telemetry import active as _tel_active
        self.telemetry = _tel_active(telemetry)
        self.epochs_seen = 0
        # priority classes over tenant indices, tightest first
        prio = derive_priorities(self.specs)
        by_class: dict[int, list[int]] = {}
        for ti, spec in enumerate(self.specs):
            by_class.setdefault(prio[spec.name], []).append(ti)
        self._classes = [_DRRClass(by_class[p]) for p in sorted(by_class)]
        self._weights = np.array([s.weight for s in self.specs])
        self._slos = np.array([math.nan if s.slo is None else s.slo
                               for s in self.specs])

    # --------------------------------------------------------------- churn
    def _advance_fleet(self, now: float) -> None:
        """Consume availability events up to ``now``; on a membership
        epoch, re-price every tenant (one plan resolution each with a
        PlanServiceModel) and re-size the DRR quantum."""
        if self._churn_times is None:
            return
        i = self._churn_idx
        if i < len(self._churn_times) and self._churn_times[i] <= now:
            while (i < len(self._churn_times)
                   and self._churn_times[i] <= now):
                i += 1
            self._churn_idx = i
            before = self.fleet.epoch
            self.fleet.advance(now)
            if self.fleet.epoch != before:
                self.epochs_seen += 1
                self._refresh_service(now)

    def _refresh_service(self, now: float,
                         epoch: int | None = None) -> None:
        self.service_model.begin_epoch(
            self.fleet.epoch if self.fleet is not None else epoch)
        model = self.service_model
        self._svc = np.array([model.service_time(n)
                              for n in self.trace.tenants])
        self._quantum = (self.config.quantum
                         if self.config.quantum is not None
                         else float(self._svc.max(initial=0.0)) or 1.0)
        # a DRR round must let the cheapest-weighted tenant afford the
        # costliest head eventually; bound pop() visits accordingly
        wmin = float(self._weights.min(initial=1.0))
        self._max_rounds = int(math.ceil(
            float(self._svc.max(initial=0.0))
            / max(self._quantum * wmin, 1e-12))) + 2

    def _epoch(self) -> int | None:
        return self.fleet.epoch if self.fleet is not None else None

    # ----------------------------------------------------------- shedding
    def _sheddable(self, idx: int, now: float) -> str | None:
        """Why request ``idx`` should be shed at dispatch instant ``now``
        (None = serve it)."""
        wait = now - self._arrival[idx]
        if (self.config.max_wait is not None
                and wait > self.config.max_wait):
            return "max_wait"
        if self.config.shed_doomed:
            ti = self._tid[idx]
            slo = self._slos[ti]
            if not math.isnan(slo) and wait + self._svc[ti] > slo:
                return "doomed"
        return None

    def _shed(self, idx: int, now: float, reason: str) -> None:
        self._status[idx] = SHED
        self._queued_total -= 1
        tel = self.telemetry
        if tel is not None:
            tel.counter("load.shed", t=now,
                        tenant=self.trace.tenants[self._tid[idx]],
                        epoch=self._epoch(), request=int(idx),
                        reason=reason,
                        parent_id=int(self._span_ids[idx]))

    # ---------------------------------------------------------- scheduling
    def _pop(self, now: float) -> int | None:
        """The next request to serve: strict priority across classes,
        weighted DRR within, shedding stale/doomed heads along the way.
        Returns a request index, or None when every queue is empty."""
        queues = self._queues
        deficit = self._deficit
        for cls in self._classes:
            tenants = cls.tenants
            n = len(tenants)
            visits = 0
            budget = n * self._max_rounds
            while visits < budget:
                ti = tenants[cls.ptr]
                q = queues[ti]
                while q:
                    reason = self._sheddable(q[0], now)
                    if reason is None:
                        break
                    self._shed(q.popleft(), now, reason)
                if not q:
                    deficit[ti] = 0.0        # empty ⇒ no banked credit
                    cls.ptr = (cls.ptr + 1) % n
                    cls.fresh = True
                    visits += 1
                    continue
                if cls.fresh:
                    deficit[ti] += self._quantum * self._weights[ti]
                    cls.fresh = False
                cost = self._svc[ti]
                if deficit[ti] >= cost - 1e-12:
                    deficit[ti] -= cost
                    return q.popleft()
                cls.ptr = (cls.ptr + 1) % n
                cls.fresh = True
                visits += 1
            # the visit budget covers the worst quantum/weight ratio, so
            # reaching it means this class's queues drained via shedding
        return None

    def _dispatch(self, now: float) -> bool:
        """Fill one free lane.  Returns False when nothing is queued."""
        idx = self._pop(now)
        if idx is None:
            return False
        ti = self._tid[idx]
        self._status[idx] = IN_FLIGHT
        self._queued_total -= 1
        self._start[idx] = now
        fin = now + self._svc[ti]
        heapq.heappush(self._busy, fin)
        self._inflight.setdefault(fin, deque()).append(idx)
        tel = self.telemetry
        if tel is not None:
            name = self.trace.tenants[ti]
            ep = self._epoch()
            sid = int(self._span_ids[idx])
            tel.counter("load.admit", t=now, tenant=name, epoch=ep,
                        request=int(idx), parent_id=sid)
            tel.span("load.queue_wait", now - self._arrival[idx],
                     t=self._arrival[idx], tenant=name, epoch=ep,
                     request=int(idx), parent_id=sid)
            tel.span("load.service", self._svc[ti], t=now, tenant=name,
                     epoch=ep, request=int(idx), parent_id=sid)
        return True

    # ------------------------------------------------------------------ run
    def run(self) -> LoadReport:
        trace, cfg = self.trace, self.config
        n = len(trace)
        self._arrival = np.asarray(trace.times)
        self._tid = np.asarray(trace.tenant_ids)
        self._status = np.zeros(n, np.int8)
        self._start = np.full(n, math.nan)
        self._finish = np.full(n, math.nan)
        self._queues: list[deque[int]] = [deque()
                                          for _ in trace.tenants]
        # pre-allocated trace-tree identity per arrival: the event loop
        # cannot hold a trace() context open across iterations, so the
        # terminal load.request claims this id as span_id and every
        # queue-wait/service/shed event cites it as parent_id
        self._span_ids = np.full(n, -1, np.int64)
        self._deficit = np.zeros(len(trace.tenants))
        self._queued_total = 0
        self._busy: list[float] = []           # finish-time min-heap
        # pending finish → request idx (finish times can collide; FIFO per
        # instant keeps it deterministic)
        self._inflight = {}
        if self.fleet is not None:
            self._churn_times = [e.time for e in self.fleet.trace.events]
            self._churn_idx = 0
        else:
            self._churn_times = None
        self._refresh_service(0.0)
        tel = self.telemetry
        tenants = trace.tenants
        cap = cfg.queue_capacity

        def finish_one(now: float) -> None:
            heapq.heappop(self._busy)
            q = self._inflight[now]
            idx = q.popleft()
            if not q:
                del self._inflight[now]
            self._status[idx] = COMPLETED
            self._finish[idx] = now
            if tel is not None:
                ti = self._tid[idx]
                lat = now - self._arrival[idx]
                slo = self._slos[ti]
                tel.span("load.request", lat, t=self._arrival[idx],
                         tenant=tenants[ti], epoch=self._epoch(),
                         request=int(idx),
                         slo_violated=bool(not math.isnan(slo)
                                           and lat > slo),
                         span_id=int(self._span_ids[idx]))

        i = 0
        now = 0.0
        while i < n or self._busy:
            next_arr = self._arrival[i] if i < n else math.inf
            next_fin = self._busy[0] if self._busy else math.inf
            if next_fin == math.inf and next_arr == math.inf:
                break
            if next_fin <= next_arr:           # lane frees first on ties
                if not cfg.drain and i >= n:
                    break                      # clock stops at last arrival
                now = next_fin
                if tel is not None:
                    tel.advance(now)
                self._advance_fleet(now)
                finish_one(now)
            else:
                now = next_arr
                if tel is not None:
                    tel.advance(now)
                self._advance_fleet(now)
                idx = i
                i += 1
                # capacity bounds the *waiting room*: an arrival that will
                # go straight to a free lane is never rejected
                if (cap is not None and self._queued_total >= cap
                        and len(self._busy) >= cfg.servers):
                    self._status[idx] = REJECTED
                    if tel is not None:
                        tel.counter("load.reject", t=now,
                                    tenant=tenants[self._tid[idx]],
                                    epoch=self._epoch(), request=int(idx),
                                    reason="queue_full")
                    continue
                if tel is not None:
                    self._span_ids[idx] = tel.allocate_span()
                self._queues[self._tid[idx]].append(idx)
                self._queued_total += 1
            while len(self._busy) < cfg.servers:
                if not self._dispatch(now):
                    break
        return LoadReport(trace=trace, specs=self.specs, config=cfg,
                          status=self._status, start=self._start,
                          finish=self._finish, clock_end=now)
