"""repro.load — open-loop, fleet-scale load generation and queueing.

HiDP's own evaluation (Figs. 7/8) replays a *closed* request list and
measures makespan; real serving is *open-loop*: arrivals keep coming
whether or not the cluster keeps up, and the interesting regime is the
queueing behaviour around saturation (the throughput-maximization line of
work — Parthasarathy & Krishnamachari, arXiv:2210.12219 / 2304.11941).
This package supplies that missing layer:

* :mod:`repro.load.traces` — seeded, replayable **arrival traces**
  (Poisson, diurnal, burst/MMPP) as immutable numpy arrays, the same
  idiom as ``repro.fleet.traces`` for availability events;
* :mod:`repro.load.service` — **service models** mapping a tenant to the
  seconds one of its requests occupies the cluster: fixed tables for
  tests, and :class:`~repro.load.service.PlanServiceModel`, which
  resolves through the membership-keyed ``PlanCache`` (one frontier pass
  per tenant per membership epoch — churn re-prices service);
* :mod:`repro.load.harness` — the **open-loop queueing harness**:
  bounded queues with arrival-time rejection (admission control),
  SLO-aware priority classes, weighted deficit round-robin fairness
  across tenants, dispatch-time shedding (backpressure), and per-decision
  telemetry (``load.admit`` / ``load.reject`` / ``load.shed`` counters,
  ``load.queue_wait`` spans, epoch-stamped);
* :mod:`repro.load.saturation` — offered-load **sweeps** producing the
  saturation-curve variants of fig7/fig8: p50/p99 latency, SLO-violation
  rate, rejects and sheds vs offered load, with or without a composed
  churn trace.

See docs/load.md for the arrival-model taxonomy, the queue lifecycle, and
the saturation-curve how-to.
"""

from .harness import (LoadConfig, LoadReport, OpenLoopHarness,  # noqa: F401
                      TenantSpec)
from .saturation import (SaturationPoint, mix_capacity,  # noqa: F401
                         saturation_sweep)
from .service import FixedServiceModel, PlanServiceModel  # noqa: F401
from .traces import ArrivalTrace  # noqa: F401

__all__ = [
    "ArrivalTrace",
    "FixedServiceModel",
    "PlanServiceModel",
    "TenantSpec",
    "LoadConfig",
    "LoadReport",
    "OpenLoopHarness",
    "SaturationPoint",
    "saturation_sweep",
    "mix_capacity",
]
