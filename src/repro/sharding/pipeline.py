"""GPipe-style pipeline parallelism over the ``pod`` axis — the TPU rendering
of HiDP's *global model partitioning* (layer blocks pipelined across nodes,
§II-A "inherently temporal").

Implementation: ``shard_map`` over ``pod``; each pod holds a contiguous layer
stage (stacked params reshaped (S, L/S, ...) and sharded on the stage dim).
Microbatches stream through a scan of M + S − 1 ticks; activations hop stages
with ``ppermute``; the last stage's outputs are zero-masked and ``psum``-ed
back to all pods.  Reverse-mode AD through scan+ppermute yields the standard
GPipe forward-then-backward schedule; the bubble fraction (S−1)/(M+S−1) is
what the HiDP global DP weighs against data partitioning's gradient
all-reduce over DCN.

Used for train/prefill shapes when the tier-1 DP picks model mode (forced
via ``dryrun.py --force-global model``), and exercised by
tests/test_pipeline.py on a CPU mesh.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import layers as L
from repro.models import transformer
from repro.models.config import ArchConfig

from ._compat import shard_map


def stage_params(cfg: ArchConfig, params: dict, n_stages: int) -> dict:
    """Reshape the stacked layer params (L, ...) → (S, L/S, ...)."""
    if cfg.n_layers % n_stages:
        raise ValueError(f"{cfg.n_layers} layers not divisible into "
                         f"{n_stages} stages")
    per = cfg.n_layers // n_stages
    out = dict(params)
    out["layers"] = jax.tree.map(
        lambda a: a.reshape((n_stages, per) + tuple(a.shape[1:])),
        params["layers"])
    return out


def stage_param_shardings(mesh: Mesh, params_staged: dict, axis: str = "pod"
                          ) -> dict:
    """Stage dim over `axis`, everything else replicated (pipeline keeps
    weights stage-resident; intra-stage TP can compose via the layer rules
    but is kept off in this reference implementation)."""
    def leaf_sh(path, leaf):
        names = [str(k.key) for k in path
                 if isinstance(k, jax.tree_util.DictKey)]
        if names and names[0] == "layers":
            return NamedSharding(mesh, P(axis))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(leaf_sh, params_staged)


def pipeline_hidden(cfg: ArchConfig, params_staged: dict, tokens: jax.Array,
                    *, mesh: Mesh, n_stages: int, microbatches: int,
                    axis: str = "pod") -> jax.Array:
    """Forward through the pipelined stack.  tokens: (B, T) int32.
    Returns final-normed hidden states (B, T, d), replicated over `axis`.
    """
    B, T = tokens.shape
    M = microbatches
    if B % M:
        raise ValueError(f"batch {B} not divisible into {M} microbatches")
    mb = B // M
    tokens_m = tokens.reshape(M, mb, T)

    layer_leaves = params_staged["layers"]
    embed_p = params_staged["embed"]
    norm_p = params_staged["final_norm"]

    def local(layers_stage, embed_local, norm_local, toks):
        # layers_stage leaves: (1, L/S, ...) → (L/S, ...)
        layers_stage = jax.tree.map(lambda a: a[0], layers_stage)
        stage = jax.lax.axis_index(axis)
        positions = jnp.broadcast_to(jnp.arange(T)[None], (mb, T))

        def run_stage(x):
            def body(c, p):
                y, _ = transformer.apply_layer(
                    cfg, p, c, mode="train", positions=positions,
                    window=None, layer_cache=None, lengths=None)
                return y, None
            y, _ = jax.lax.scan(body, x, layers_stage)
            return y

        d = cfg.d_model
        zero = jnp.zeros((mb, T, d), jnp.bfloat16)
        outs0 = jnp.zeros((M, mb, T, d), jnp.bfloat16)
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            buf, outs = carry
            mb_idx = jnp.clip(t, 0, M - 1)
            emb = L.embed(embed_local, tokens_m_local[mb_idx]
                          ).astype(jnp.bfloat16)
            x_in = jnp.where(stage == 0, emb, buf)
            y = run_stage(x_in)
            # last stage finished microbatch (t − S + 1)
            out_idx = t - (n_stages - 1)
            is_out = (stage == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                is_out,
                lambda o: o.at[jnp.clip(out_idx, 0, M - 1)].set(y),
                lambda o: o, outs)
            y_next = jax.lax.ppermute(y, axis, perm)
            return (y_next, outs), None

        tokens_m_local = toks                       # (M, mb, T) replicated
        (buf, outs), _ = jax.lax.scan(
            tick, (zero, outs0), jnp.arange(M + n_stages - 1))
        # only the last stage holds real outputs — psum the masked stack
        outs = jnp.where(stage == n_stages - 1, outs, 0)
        outs = jax.lax.psum(outs, axis)
        x = outs.reshape(B, T, d)
        return L.apply_norm(cfg, norm_local, x)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), layer_leaves),
                  jax.tree.map(lambda _: P(), embed_p),
                  jax.tree.map(lambda _: P(), norm_p),
                  P()),
        out_specs=P(),
        check_vma=False)
    return fn(layer_leaves, embed_p, norm_p, tokens_m)


def make_pipeline_train_step(model, opt_cfg, plan, mesh):
    """Pipeline-parallel training step (CE loss over the pipelined hidden).

    Composes with the data-parallel axes only through the batch dimension
    staying un-sharded here (reference implementation, stage-resident
    weights); the HiDP planner prices this against data mode via the bubble
    term."""
    from repro.training import optimizer as optim
    from repro.training.train_loop import chunked_ce_loss

    cfg = model.cfg
    S = plan.pipeline_stages
    M = max(plan.microbatches, S)

    def loss_fn(params_staged, batch):
        hidden = pipeline_hidden(cfg, params_staged, batch["tokens"],
                                 mesh=mesh, n_stages=S, microbatches=M)
        return chunked_ce_loss(model, params_staged, hidden,
                               batch["targets"], chunks=8)

    def train_step(params_staged, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params_staged, batch)
        params_staged, opt_state, metrics = optim.apply_updates(
            opt_cfg, params_staged, grads, opt_state)
        metrics["loss"] = loss
        return params_staged, opt_state, metrics

    return train_step
