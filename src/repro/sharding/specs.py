"""Render a HiDP ShardingPlan into concrete jax.sharding.NamedSharding trees
for parameters, optimizer state, batches and caches.

Rules are name-based on the trailing dims of each leaf (stack dims — layer,
group, expert-group — are padded with None on the left), so the same table
serves the flat decoder stack, whisper's enc/dec stacks and the VLM's
two-level stack.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .plan import ShardingPlan


def _ax(axes: tuple[str, ...]):
    """() → None; (a,) → a; (a,b) → (a,b) for PartitionSpec entries."""
    if not axes:
        return None
    if len(axes) == 1:
        return axes[0]
    return tuple(axes)


# trailing-dims spec table: name → function(plan) -> tuple of entries
def _param_rules(plan: ShardingPlan) -> dict[str, tuple]:
    tp = _ax(plan.tp_axes)
    fs = _ax(plan.fsdp_axes)
    return {
        # embeddings
        "embedding": (tp, fs),
        "head": (fs, tp),
        # attention
        "wq": (fs, tp), "wk": (fs, tp), "wv": (fs, tp), "wo": (tp, fs),
        # dense mlp
        "w_gate": (fs, tp), "w_up": (fs, tp), "w_down": (tp, fs),
        # moe (experts sharded over tp = expert parallelism; the dense
        # fallback also benefits: each chip computes only its expert shard).
        # _moe_rules() overrides these when E does not divide the tp axes.
        "router": (fs, None),
        "moe/w_gate": (tp, fs, None), "moe/w_up": (tp, fs, None),
        "moe/w_down": (tp, None, fs),
        # mamba
        "w_in": (fs, tp), "w_out": (tp, fs), "conv": (None, tp),
        "A_log": (tp,), "D": (tp,), "dt_bias": (tp,), "norm": (tp,),
        # norms / gates
        "w": (None,), "b": (None,),
        "gate_attn": (), "gate_mlp": (),
        "ln1": (None,), "ln2": (None,), "lnx": (None,),
    }


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            out.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            out.append(k.name)
    return out


def param_pspec(path, leaf, plan: ShardingPlan) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    rules = _param_rules(plan)
    moe_ctx = any(n == "moe" for n in names)
    key = f"moe/{name}" if moe_ctx and f"moe/{name}" in rules else name
    ndim = len(leaf.shape)
    if key not in rules:
        return P()                                  # replicate unknowns
    if moe_ctx and key.startswith("moe/"):
        # expert count may not divide the tp axes (mixtral: 8e over a
        # 16-wide axis) — shard the expert-FF dim instead so the 90 GB of
        # expert weights never replicate
        n_experts = leaf.shape[-3]
        tp_size = 1
        for a in plan.tp_axes:
            tp_size *= plan.mesh.size(a)
        if n_experts % max(tp_size, 1) != 0:
            tp = _ax(plan.tp_axes)
            fs = _ax(plan.fsdp_axes)
            rules = dict(rules)
            rules["moe/w_gate"] = (None, fs, tp)
            rules["moe/w_up"] = (None, fs, tp)
            rules["moe/w_down"] = (None, tp, fs)
    tail = rules[key]
    tail = tail[:ndim]
    pad = ndim - len(tail)
    return P(*([None] * pad + list(tail)))


def sanitize(mesh: Mesh, spec: P, shape: tuple[int, ...]) -> P:
    """pjit in_shardings require every sharded dim to divide evenly; drop
    axes (largest-first) from entries that do not divide."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, entries):
        if e is None:
            out.append(None)
            continue
        axes = list(e) if isinstance(e, tuple) else [e]
        while axes:
            total = 1
            for a in axes:
                total *= sizes[a]
            if dim % total == 0:
                break
            axes.sort(key=lambda a: sizes[a])
            axes.pop()                       # drop the largest axis
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def param_shardings(mesh: Mesh, specs_tree: Any, plan: ShardingPlan) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, sanitize(mesh, param_pspec(path, leaf, plan), leaf.shape)),
        specs_tree)


# --------------------------------------------------------------------------
# Batches
# --------------------------------------------------------------------------

def batch_pspec(name: str, leaf, plan: ShardingPlan) -> P:
    b = _ax(plan.batch_axes)
    s = _ax(plan.seq_axes)
    ndim = len(leaf.shape)
    if name in ("tokens", "targets"):
        return P(b, s) if ndim == 2 else P(b)
    if name == "lengths":
        return P(b)
    if name == "frames":            # (B, T_enc, d)
        return P(b, s, None)
    if name == "vision":            # (B, Nv, d)
        return P(b, None, None)
    return P()


def batch_shardings(mesh: Mesh, batch_tree: dict, plan: ShardingPlan) -> dict:
    return {k: NamedSharding(mesh, sanitize(mesh, batch_pspec(k, v, plan),
                                            v.shape))
            for k, v in batch_tree.items()}


# --------------------------------------------------------------------------
# Caches
# --------------------------------------------------------------------------

def cache_pspec(path, leaf, plan: ShardingPlan) -> P:
    names = _path_names(path)
    name = names[-1]
    b = _ax(plan.batch_axes)
    s = _ax(plan.seq_axes)
    tp = _ax(plan.tp_axes)
    ndim = len(leaf.shape)
    if name in ("k", "v"):          # (..., B, S, Hkv, hd)
        # KV-head counts often do not divide the tp axes (GQA kv ∈ {1,4,5,8}
        # vs 16-way model axis); those axes shard the cache *sequence* dim
        # instead (context parallelism) — without this the cache replicates.
        hkv = leaf.shape[-2]
        head_axes, seq_extra = [], list(plan.seq_axes)
        acc = 1
        for a in plan.tp_axes:
            size = plan.mesh.size(a)
            if hkv % (acc * size) == 0:
                head_axes.append(a)
                acc *= size
            else:
                seq_extra.append(a)
        tail = (b, _ax(tuple(seq_extra)), _ax(tuple(head_axes)), None)
    elif name in ("xk", "xv"):      # (..., B, Nv, Hkv, hd)
        tail = (b, None, tp, None)
    elif name == "h":               # (..., B, nh, hd, n)
        tail = (b, tp, None, None)
    elif name == "conv":            # (..., B, cw-1, C)
        tail = (b, None, tp)
    else:
        return P()
    tail = tail[-ndim:] if len(tail) > ndim else tail
    pad = ndim - len(tail)
    return P(*([None] * pad + list(tail)))


def cache_shardings(mesh: Mesh, cache_tree: Any, plan: ShardingPlan) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, sanitize(mesh, cache_pspec(path, leaf, plan), leaf.shape)),
        cache_tree)


# --------------------------------------------------------------------------
# Outputs
# --------------------------------------------------------------------------

def logits_sharding(mesh: Mesh, plan: ShardingPlan,
                    shape: tuple[int, ...] | None = None) -> NamedSharding:
    spec = P(_ax(plan.batch_axes), None, _ax(plan.tp_axes))
    if shape is not None:
        spec = sanitize(mesh, spec, shape)
    return NamedSharding(mesh, spec)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
