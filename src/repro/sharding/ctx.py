"""Activation-sharding context.

XLA's sharding propagation cannot always infer the intended layout of
intermediate activations through scan-over-layers and the CE loss (it
replicates on conflict, which at 1M tokens × 256k vocab is catastrophic).
The launcher publishes the HiDP plan's activation/logits PartitionSpecs here
and the model code pins them with ``with_sharding_constraint`` at layer
boundaries — a no-op when no plan is active (CPU smoke tests) or when
tracing without a mesh.
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

_ACT_SPEC: P | None = None          # (batch, seq, d)
_LOGITS_SPEC: P | None = None       # (batch, seq, vocab)
_MESH = None                        # concrete Mesh for shard_map paths
_EP_AXIS: str | tuple | None = None  # expert-parallel mesh axis
_ACT_SHARD_SPEC: P | None = None    # per-device activation blocks for EP


def set_specs(act: P | None, logits: P | None, mesh=None,
              ep_axis=None) -> None:
    global _ACT_SPEC, _LOGITS_SPEC, _MESH, _EP_AXIS
    _ACT_SPEC, _LOGITS_SPEC = act, logits
    _MESH, _EP_AXIS = mesh, ep_axis


@contextlib.contextmanager
def plan_specs(act: P | None, logits: P | None, mesh=None, ep_axis=None):
    prev = (_ACT_SPEC, _LOGITS_SPEC, _MESH, _EP_AXIS)
    set_specs(act, logits, mesh, ep_axis)
    try:
        yield
    finally:
        set_specs(*prev)


def get_mesh():
    return _MESH


def get_ep_axis():
    return _EP_AXIS


def get_act_spec() -> P | None:
    return _ACT_SPEC


def _constrain(x: jax.Array, spec: P | None) -> jax.Array:
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x                      # no mesh in scope (unit tests)


def constrain_act(x: jax.Array) -> jax.Array:
    """Pin a (B, T, d) activation to the plan's layout."""
    return _constrain(x, _ACT_SPEC)


def constrain_logits(x: jax.Array) -> jax.Array:
    return _constrain(x, _LOGITS_SPEC)
