"""jax version compatibility for ``shard_map``.

Newer jax exposes ``jax.shard_map(..., check_vma=)``; older releases
(through 0.4.x) ship it as ``jax.experimental.shard_map.shard_map`` with
the same knob spelled ``check_rep=``.  Resolve whichever this
environment has once, behind a single signature (the modern one), so the
sharded model code (``repro.models.moe_ep``, ``repro.sharding.pipeline``)
runs on both sides of the rename.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)
