"""HiDP planning for the TPU tier — the paper's two-tier strategy driving
real sharding decisions (DESIGN.md §2 table).

Tier 1 (global, across pods): the core DP (``repro.core.dp_partitioner``)
runs on the model's block DAG with pods collapsed to (Λ_pod, β_DCN)
resources — exactly Alg. 1 lines 4-6 — choosing **data** (batch/context over
the ``pod`` axis) vs **model** (pipeline stages over ``pod``) partitioning,
and the stage boundaries when model mode wins.

Tier 2 (local, intra-pod): the DSE agent enumerates concrete mesh layouts —
the TPU analogue of the paper's P1–P9 sweep (Fig. 1) — and costs each with a
three-term roofline model (compute / HBM / ICI-collectives, the ψ = λ/μ
ratio in vector form).  P1 (pure data parallelism with replicated params,
the "default framework" behaviour) is always in the candidate set and is
rejected by the cost model exactly when the paper says it should be.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any

from repro.core import cost_model as cm
from repro.core import dp_partitioner
from repro.core.dag import DataPartition, ModelDAG, ModelPartition
from repro.models.config import ArchConfig, ShapeConfig
from repro.models.model import Model

HBM_PER_CHIP = 16e9          # v5e
CHIP = dict(peak=cm.TPU_V5E_PEAK_FLOPS, hbm=cm.TPU_V5E_HBM_BW,
            ici=cm.TPU_V5E_ICI_BW, dcn=cm.TPU_V5E_DCN_BW)


@dataclasses.dataclass(frozen=True)
class MeshDesc:
    axes: tuple[str, ...]
    shape: tuple[int, ...]

    @property
    def n_pods(self) -> int:
        return self.shape[self.axes.index("pod")] if "pod" in self.axes else 1

    @property
    def chips_per_pod(self) -> int:
        n = 1
        for a, s in zip(self.axes, self.shape):
            if a != "pod":
                n *= s
        return n

    @property
    def total_chips(self) -> int:
        return self.n_pods * self.chips_per_pod

    def size(self, axis: str) -> int:
        return self.shape[self.axes.index(axis)] if axis in self.axes else 1


SINGLE_POD = MeshDesc(("data", "model"), (16, 16))
MULTI_POD = MeshDesc(("pod", "data", "model"), (2, 16, 16))


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    arch: str
    shape: str
    mesh: MeshDesc
    global_mode: str                       # "data" | "model" (across pods)
    local_layout: str                      # candidate id (P1-analogue names)
    batch_axes: tuple[str, ...]            # batch dim of activations
    seq_axes: tuple[str, ...] = ()         # context/cache parallelism
    tp_axes: tuple[str, ...] = ("model",)
    fsdp_axes: tuple[str, ...] = ()
    pipeline_stages: int = 1               # >1 → GPipe over 'pod'
    pipeline_boundaries: tuple[int, ...] = ()
    microbatches: int = 1
    remat_group: int = 1                   # checkpoint every N layers
    opt_dtype: str = "float32"             # AdamW m/v dtype
    param_dtype: str = "float32"           # bf16 + fp32 master → ½ coll bytes
    moe_impl: str = "dense"
    remat: bool = True
    predicted: dict = dataclasses.field(default_factory=dict)
    planning_seconds: float = 0.0

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.size(a)
        return n


# --------------------------------------------------------------------------
# Per-candidate three-term cost model (the local ψ in roofline form)
# --------------------------------------------------------------------------

def _train_bytes_per_chip(cfg: ArchConfig, shape: ShapeConfig,
                          cand: dict, mesh: MeshDesc) -> float:
    """Resident bytes per chip: fp32 params + AdamW m/v + activation
    checkpoints for one microbatch + gradients + loss working set."""
    shards = 1
    for a in set(cand["tp_axes"]) | set(cand["fsdp_axes"]):
        shards *= mesh.size(a)
    if cand.get("pipeline_stages", 1) > 1:
        shards *= cand["pipeline_stages"]
    p_total = cfg.params_total()
    sd = 2 if cand.get("opt_dtype") == "bfloat16" else 4
    pd = 2 if cand.get("param_dtype") == "bfloat16" else 4
    master = 4 if pd == 2 else 0
    # w, m, v, grad (+ fp32 master when params are bf16)
    param_state = p_total * (pd + sd + sd + pd + master) / shards
    tokens = shape.global_batch * shape.seq_len
    tok_local = tokens / max(cand["dp_size"], 1) / max(cand["microbatches"], 1)
    g = max(cand.get("remat_group", 1), 1)
    tp = 1
    for a in cand["tp_axes"]:
        tp *= mesh.size(a)
    # one checkpoint per layer *group*; ×6 bytes/elem: the bf16 stack plus
    # the f32 copy XLA materialises when the backward loop consumes it in
    # fp32 (observed in the compiled HLO; priced in to stay honest)
    act = tok_local * cfg.d_model * 6.0 * (cfg.n_layers / g + 2)
    # live group's backward working set: residual streams (4 × d, unsharded
    # by tp) + matmul output activations (≈ per-layer params / d_model output
    # features, sharded by tp), in fp32-ish units
    out_features = (p_total / max(cfg.n_layers, 1)) / max(cfg.d_model, 1)
    act += g * tok_local * (4.0 * cfg.d_model + out_features / tp) * 4.0
    # chunked-CE loss slice (fp32 logits + grad, 8 chunks)
    act += 3.0 * (tok_local / 8) * cfg.vocab * 4 / tp
    return param_state + act


def _decode_bytes_per_chip(cfg: ArchConfig, shape: ShapeConfig,
                           cand: dict, mesh: MeshDesc) -> float:
    shards = 1
    for a in set(cand["tp_axes"]) | set(cand["fsdp_axes"]):
        shards *= mesh.size(a)
    params = cfg.params_total() * 2.0 / shards             # bf16 serving
    cache_shards = max(cand["dp_size"], 1) * math.prod(
        [mesh.size(a) for a in cand["tp_axes"]])
    cache = _cache_bytes(cfg, shape) / cache_shards
    return params + cache


def _cache_bytes(cfg: ArchConfig, shape: ShapeConfig) -> float:
    B, S = shape.global_batch, shape.seq_len
    total = 0.0
    if cfg.family != "ssm":
        total += cfg.n_layers * B * S * cfg.n_kv_heads * cfg.hd * 2 * 2
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        total += cfg.n_layers * B * s.n_heads(cfg.d_model) * s.head_dim \
            * s.d_state * 4
    return total


def _collective_bytes_per_chip(cfg: ArchConfig, shape: ShapeConfig,
                               cand: dict, mesh: MeshDesc,
                               kind: str) -> float:
    """Ring-model per-chip collective traffic per step (ICI terms)."""
    tokens = shape.global_batch * (1 if kind == "decode" else shape.seq_len)
    dp = max(cand["dp_size"], 1)
    tp = math.prod([mesh.size(a) for a in cand["tp_axes"]]) or 1
    p_total = cfg.params_total()
    by = 0.0
    pd = 2.0 if cand.get("param_dtype") == "bfloat16" else 4.0
    ep_mode = cand.get("moe_impl", "dense").startswith("ep_a2a") \
        and cfg.moe is not None
    p_expert = 0.0
    if cfg.moe is not None:
        p_expert = (cfg.moe.num_experts * 3.0 * cfg.d_model
                    * cfg.moe.d_ff_expert * cfg.n_layers)
    if kind == "train":
        # gradient reduce-scatter + param all-gather (or all-reduce): ring;
        # bf16 params → bf16 grads/gathers (half the bytes)
        p_dense_grads = p_total - (p_expert if ep_mode else 0.0)
        by += 2.0 * (p_dense_grads * pd / tp) * (dp - 1) / dp
        if cand["fsdp_axes"]:
            by += 2.0 * (p_dense_grads * pd / tp) * (dp - 1) / dp
        if ep_mode:
            # expert grads live on their owner rank: reduce over the data
            # axis only (the EP axis never sees other ranks' expert grads)
            ep = tp if tp > 1 else math.prod(
                [mesh.size(a) for a in cand["seq_axes"]]) or 1
            dp_b = max(dp // max(ep, 1), 1) if not cand["tp_axes"] else dp
            by += 2.0 * (p_expert * pd / ep) * (dp_b - 1) / max(dp_b, 1) * (
                2.0 if cand["fsdp_axes"] else 1.0)
    elif cand["fsdp_axes"]:
        # inference param gathers; under EP the expert weights (the bulk of
        # an MoE) are resident on their owner rank and never gathered
        by += ((p_total - (p_expert if ep_mode else 0.0)) * 2 / tp)
    if cand["seq_axes"] and cfg.family != "ssm" and kind != "decode":
        # sequence-parallel attention: per-chip KV gather per layer
        b_sh = 1
        for a in cand["batch_axes"]:
            b_sh *= mesh.size(a)
        kv_dims = 2 * cfg.n_kv_heads * cfg.hd
        by += (shape.global_batch / max(b_sh, 1)) * shape.seq_len \
            * kv_dims * 2 * cfg.n_layers * (3 if kind == "train" else 1)
    if tp > 1:
        # 2 all-reduces of activations per layer (attn out + mlp out)
        per_chip_tokens = tokens / dp
        by += (2 * cfg.n_layers * 2.0 * per_chip_tokens * cfg.d_model * 2
               * (tp - 1) / tp) * (3 if kind == "train" else 1)
    if ep_mode:
        # a2a out + back of the routed token slice; when tokens are
        # pre-sharded over the EP axis (sequence parallel) there is no
        # output all-gather
        seq_sharded = bool(cand["seq_axes"]) and not cand["tp_axes"]
        ep = tp if tp > 1 else math.prod(
            [mesh.size(a) for a in cand["seq_axes"]]) or 1
        per_chip_tokens = tokens / dp
        t_ep = per_chip_tokens if seq_sharded else per_chip_tokens / ep
        a2a_bytes = 1.25 if cand["moe_impl"] == "ep_a2a_q8" else 2.0
        per_layer = 4.0 * t_ep * cfg.moe.top_k * cfg.moe.capacity_factor \
            * cfg.d_model * a2a_bytes
        if not seq_sharded:
            per_layer += 2.0 * per_chip_tokens * cfg.d_model * 2
        by += per_layer * cfg.n_layers * (3 if kind == "train" else 1)
    return by


def _candidate_cost(model: Model, shape: ShapeConfig, cand: dict,
                    mesh: MeshDesc) -> dict:
    cfg = model.cfg
    kind = shape.kind
    chips = mesh.total_chips
    flops = model.step_flops(shape)
    if cfg.moe is not None and cand.get("moe_impl", "dense") == "dense":
        # the dense baseline computes every expert for every token: its
        # executed FLOPs exceed the useful ones by (E/top_k − 1)× on the ffn
        waste = cfg.moe.num_experts / cfg.moe.top_k
        impl_flops = flops + (waste - 1) * _moe_ffn_share(cfg, shape)
    else:
        impl_flops = flops
    compute = impl_flops / (chips * CHIP["peak"])
    if cand.get("pipeline_stages", 1) > 1:
        s, m = cand["pipeline_stages"], max(cand["microbatches"], 1)
        compute *= 1.0 + (s - 1) / m                        # bubble
    # dense-MoE materialises (tokens, E_local, ffe) intermediates (~4 live
    # tensors); experts that do not divide the tp axes replicate entirely
    moe_tmp = 0.0
    if cfg.moe is not None and cand.get("moe_impl", "dense") == "dense":
        tok = shape.global_batch * (1 if kind == "decode" else shape.seq_len)
        tok_local = tok / max(cand["dp_size"], 1) \
            / max(cand["microbatches"], 1)
        tp = 1
        for a in cand["tp_axes"]:
            tp *= mesh.size(a)
        e_local = (cfg.moe.num_experts // tp
                   if cfg.moe.num_experts % tp == 0 else cfg.moe.num_experts)
        moe_tmp = tok_local * e_local * cfg.moe.d_ff_expert * 2.0 * 4
    if kind == "train":
        resident = _train_bytes_per_chip(cfg, shape, cand, mesh) + moe_tmp
        hbm_traffic = cfg.params_total() * 4 / (
            cand["param_shards"]) * (3 if cand["microbatches"] == 1
                                     else 2 + cand["microbatches"])
    else:
        resident = _decode_bytes_per_chip(cfg, shape, cand, mesh) + moe_tmp
        hbm_traffic = resident                              # read weights+cache
    memory = hbm_traffic / CHIP["hbm"]
    coll = _collective_bytes_per_chip(cfg, shape, cand, mesh, kind) \
        / CHIP["ici"]
    fits = resident <= HBM_PER_CHIP * 0.92
    total = max(compute, memory, coll) if fits else float("inf")
    return dict(compute=compute, memory=memory, collective=coll,
                resident=resident, fits=fits, total=total)


def _moe_ffn_share(cfg: ArchConfig, shape: ShapeConfig) -> float:
    m = cfg.moe
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    f = tokens * m.top_k * 2.0 * 3 * cfg.d_model * m.d_ff_expert * cfg.n_layers
    return f * (3.0 if shape.kind == "train" else 1.0)


# --------------------------------------------------------------------------
# Candidate enumeration (tier-2 DSE agent)
# --------------------------------------------------------------------------

def _enumerate_candidates(cfg: ArchConfig, shape: ShapeConfig,
                          mesh: MeshDesc, global_mode: str) -> list[dict]:
    """Concrete mesh layouts = the P1..P9 analogue.  'pod' participates in
    batch/context axes when the global tier chose data mode; in pipeline
    stages when it chose model mode."""
    pod_in_data = global_mode == "data" and mesh.n_pods > 1
    pod_axes = ("pod",) if pod_in_data else ()
    pstages = mesh.n_pods if (global_mode == "model" and mesh.n_pods > 1) else 1
    B = shape.global_batch
    out: list[dict] = []

    def cand(name, batch_axes, seq_axes=(), tp=(), fsdp=(), micro=1,
             moe="dense", rg=1, od="float32", pd="float32"):
        # effective batch sharding: drop axes (largest first) until the batch
        # divides — mirrors specs.sanitize so predicted dp == realised dp
        baxes = list(batch_axes)
        while baxes:
            prod = 1
            for a in baxes:
                prod *= mesh.size(a)
            if B % prod == 0:
                break
            baxes.sort(key=mesh.size)
            baxes.pop()
        batch_axes = tuple(baxes)
        dp = 1
        for a in batch_axes + seq_axes:
            dp *= mesh.size(a)
        # microbatching must keep the per-microbatch batch divisible by dp
        if micro > 1 and (B % (micro * dp) != 0 if not
                          _shards_seq(batch_axes, seq_axes) else False):
            return
        shards = pstages
        for a in set(tp) | set(fsdp):
            shards *= mesh.size(a)
        out.append(dict(name=name, batch_axes=batch_axes, seq_axes=seq_axes,
                        tp_axes=tp, fsdp_axes=fsdp, microbatches=micro,
                        moe_impl=moe, dp_size=dp, param_shards=max(shards, 1),
                        pipeline_stages=pstages, remat_group=rg,
                        opt_dtype=od, param_dtype=pd))

    def _shards_seq(batch_axes, seq_axes):
        return bool(seq_axes)

    if shape.kind == "train":
        for m in (1, 2, 4, 8):
            for rg in (1, 2, 4, 8):
                if cfg.n_layers % rg:
                    continue
                for od in ("float32", "bfloat16"):
                    for pd in ("float32", "bfloat16"):
                        # P1: framework default — pure DP, replicated params
                        cand("P1_pure_dp", pod_axes + ("data", "model"),
                             micro=m, rg=rg, od=od, pd=pd)
                        cand("dp_tp", pod_axes + ("data",), tp=("model",),
                             micro=m, rg=rg, od=od, pd=pd)
                        cand("dp_tp_fsdp", pod_axes + ("data",),
                             tp=("model",), fsdp=("data",), micro=m, rg=rg,
                             od=od, pd=pd)
                        cand("fsdp_all", pod_axes + ("data", "model"),
                             fsdp=("data", "model"), micro=m, rg=rg, od=od,
                             pd=pd)
                        cand("dp_sp_fsdp", pod_axes + ("data",),
                             seq_axes=("model",), fsdp=("data", "model"),
                             micro=m, rg=rg, od=od, pd=pd)
    elif shape.kind == "prefill":
        cand("P1_pure_dp", pod_axes + ("data", "model"))
        cand("dp_tp", pod_axes + ("data",), tp=("model",))
        cand("dp_tp_fsdp", pod_axes + ("data",), tp=("model",),
             fsdp=("data",))
        # no-TP layout: batch over data, sequence over model, params FSDP
        # over both — trades the per-layer TP activation all-reduces
        # (∝ tokens·d_model) for attention KV gathers (∝ tokens·kv_dims,
        # ≥8× smaller under GQA) + param all-gathers
        cand("dp_sp_fsdp", pod_axes + ("data",), seq_axes=("model",),
             fsdp=("data", "model"))
        if B < 32:
            cand("seq_tp", pod_axes, seq_axes=("data",), tp=("model",))
    else:                                   # decode
        cand("P1_pure_dp", pod_axes + ("data", "model"))
        cand("dp_tp", pod_axes + ("data",), tp=("model",))
        if cfg.family not in ("ssm", "hybrid"):
            # context parallelism: shard the KV cache sequence dim
            cand("ctx_tp", pod_axes, seq_axes=("data",), tp=("model",))
        cand("tp_all", pod_axes, tp=("data", "model")
             if B == 1 else ("model",))
    # MoE: expert-parallel variants (including the sequence-parallel one,
    # where tokens are pre-sharded over the EP axis: no output all-gather and
    # expert gradients reduce over the data axis only)
    if cfg.moe is not None:
        base = [c for c in list(out)
                if c["name"] in ("dp_tp", "dp_tp_fsdp", "ctx_tp",
                                 "dp_sp_fsdp")]
        for c in base:
            c2 = dict(c)
            c2["name"] = c["name"] + "_ep"
            c2["moe_impl"] = "ep_a2a"
            out.append(c2)
            c3 = dict(c)
            c3["name"] = c["name"] + "_ep_q8"
            c3["moe_impl"] = "ep_a2a_q8"
            out.append(c3)
    return out


# --------------------------------------------------------------------------
# Tier-1 global DP (pods as nodes) + plan assembly
# --------------------------------------------------------------------------

def _pods_as_cluster(mesh: MeshDesc) -> cm.Cluster:
    return cm.Cluster(tuple(cm.tpu_pod(f"pod{i}", mesh.chips_per_pod)
                            for i in range(mesh.n_pods)))


def plan_tpu(model: Model, shape: ShapeConfig, mesh: MeshDesc,
             *, force_layout: str | None = None,
             force_global: str | None = None,
             moe_impl: str | None = None) -> ShardingPlan:
    """Two-tier HiDP planning for one (arch × shape × mesh) cell."""
    t0 = time.perf_counter()
    cfg = model.cfg
    dag = model.block_costs(shape)
    boundaries: tuple[int, ...] = ()
    if mesh.n_pods > 1:
        cluster = _pods_as_cluster(mesh)
        resources = [
            dataclasses.replace(cm.node_as_resource(n), rtt=5e-5,
                                bw=CHIP["dcn"])
            for n in cluster.nodes]
        gpart = dp_partitioner.partition(dag, resources)
        global_mode = ("model" if isinstance(gpart, ModelPartition)
                       else "data")
        if isinstance(gpart, ModelPartition):
            boundaries = gpart.boundaries
    else:
        global_mode = "data"
    if force_global:
        global_mode = force_global

    # Rendering of global model-mode: for train/prefill it becomes GPipe
    # stages over 'pod'; for decode (no microbatch stream to fill a pipeline
    # with) it becomes tensor parallelism extended over the pod axis.
    decode_pod_tp = (shape.kind == "decode" and global_mode == "model"
                     and mesh.n_pods > 1)

    cands = _enumerate_candidates(cfg, shape, mesh, global_mode)
    if decode_pod_tp:
        for c in cands:
            c["tp_axes"] = ("pod",) + tuple(c["tp_axes"])
            c["pipeline_stages"] = 1
            c["param_shards"] = max(c["param_shards"], 1) * mesh.n_pods
    best, best_cost = None, None
    for c in cands:
        if force_layout and c["name"] != force_layout:
            continue
        if moe_impl and c["moe_impl"] != moe_impl:
            continue
        cost = _candidate_cost(model, shape, c, mesh)
        if best is None or cost["total"] < best_cost["total"]:
            best, best_cost = c, cost
    if best is None or not best_cost["fits"]:
        # nothing fits the 16 GB budget: take the minimum-resident candidate
        # (the least-bad memory plan) rather than an arbitrary one
        scored = [(c, _candidate_cost(model, shape, c, mesh)) for c in cands
                  if (not force_layout or c["name"] == force_layout)
                  and (not moe_impl or c["moe_impl"] == moe_impl)]
        best, best_cost = min(scored, key=lambda cc: cc[1]["resident"])
    return ShardingPlan(
        arch=cfg.name, shape=shape.name, mesh=mesh,
        global_mode=global_mode, local_layout=best["name"],
        batch_axes=tuple(best["batch_axes"]),
        seq_axes=tuple(best["seq_axes"]),
        tp_axes=tuple(best["tp_axes"]),
        fsdp_axes=tuple(best["fsdp_axes"]),
        pipeline_stages=best.get("pipeline_stages", 1),
        pipeline_boundaries=boundaries,
        microbatches=best["microbatches"],
        remat_group=best.get("remat_group", 1),
        opt_dtype=best.get("opt_dtype", "float32"),
        param_dtype=best.get("param_dtype", "float32"),
        moe_impl=best["moe_impl"],
        predicted=best_cost,
        planning_seconds=time.perf_counter() - t0)
