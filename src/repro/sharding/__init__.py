# Keep this package import-light: models import repro.sharding.ctx, and
# plan.py imports the models package — a heavy __init__ here would be a cycle.
from . import ctx  # noqa: F401
