"""FleetController — membership epochs over a live cluster.

The controller owns the availability machinery the seed already had but
nothing consumed end-to-end: a ``ClusterManager`` (availability vector +
leader election, Alg. 1 lines 2–3) and its ``HeartbeatMonitor``.  It
replays a :class:`~repro.fleet.traces.ChurnTrace` against them and turns
raw events into **membership epochs** — the unit every churn-aware
consumer keys on:

* :meth:`advance` applies every unconsumed event up to ``now``.
  Simultaneously-applied events coalesce into at most **one** new epoch,
  so a consumer that re-plans per epoch re-plans once per membership
  change, not once per event.  Each epoch records its time, availability
  mask, :func:`~repro.core.fingerprint.membership_fingerprint`, leader and
  triggering events (``epochs`` is the full history).
* leadership is maintained across churn: when the sitting leader goes
  unavailable the controller immediately fails over to the first available
  node (``ClusterManager.elect_leader``) — ``leader_elections`` counts
  hand-offs.
* ``on_epoch`` (a callback taking the new :class:`MembershipEpoch`) fires
  exactly once per epoch — wire
  ``ServingEngine.on_membership_change`` to re-enter EXPLORE with one
  frontier re-plan per in-flight tenant; with a membership-keyed
  ``PlanCache`` each of those re-plans is a single miss for a brand-new
  membership and a pure warm hit for a returning one.
* ``feedback`` (a ``repro.profiling.FeedbackLoop``) is told to
  :meth:`~repro.profiling.FeedbackLoop.forget_resource` a node's drift
  windows when it goes down, so a returning node's first measurements are
  judged on their own — not against a window straddling the outage.

The controller also exposes the *peek* the simulator's fault-injection
path needs: :meth:`next_failure` finds the earliest unconsumed ``crash``
inside an execution window that hits a node the plan actually uses,
without consuming it — the consume happens via :meth:`advance` once the
failure is handled.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

from repro.core.cluster import ClusterManager
from repro.core.cost_model import Cluster
from repro.core.fingerprint import membership_fingerprint

from .traces import ChurnEvent, ChurnTrace


@dataclasses.dataclass(frozen=True)
class MembershipEpoch:
    """One membership generation: who is in the fleet, since when, led by
    whom, and which events created it."""

    epoch: int
    time: float
    mask: tuple[bool, ...]
    fingerprint: str
    leader: str | None
    events: tuple[ChurnEvent, ...] = ()

    def available(self) -> int:
        return sum(self.mask)


class FleetController:
    """Replays a :class:`ChurnTrace` into membership epochs.

    Attributes:
        manager: the owned ``ClusterManager`` (availability + leadership).
        trace: the replayable event schedule (never mutated; the
            controller's cursor tracks consumption).
        epoch: the current epoch number (0 = the initial membership).
        epochs: full epoch history, ``epochs[-1]`` current.
        leader_elections: leader hand-offs forced by churn.
        telemetry: optional ``repro.telemetry.TelemetryRecorder`` — every
            closed epoch lands as a ``fleet.membership`` gauge (value =
            available-node count) and every forced hand-off as a
            ``fleet.leader_election`` counter.
    """

    def __init__(self, cluster: Cluster | ClusterManager,
                 trace: ChurnTrace | None = None, *,
                 leader: str | None = None,
                 on_epoch: Callable[[MembershipEpoch], object] | None = None,
                 feedback=None, telemetry=None):
        self.manager = (cluster if isinstance(cluster, ClusterManager)
                        else ClusterManager(cluster))
        self.trace = trace if trace is not None else ChurnTrace()
        self.on_epoch = on_epoch
        self.feedback = feedback
        from repro.telemetry import active as _tel_active
        self.telemetry = _tel_active(telemetry)
        self.leader_elections = 0
        self._cursor = 0
        self._epoch_hooks: list[Callable[[MembershipEpoch], object]] = []
        self.now = 0.0
        if leader is not None:
            self.manager.elect_leader(leader)
        elif not self.manager.leader_available():
            self._elect_fallback(count=False)
        self.epochs: list[MembershipEpoch] = [MembershipEpoch(
            epoch=0, time=0.0, mask=self.membership_mask(),
            fingerprint=self.membership_fingerprint(),
            leader=self.manager.leader)]

    # ------------------------------------------------------------- accessors
    @property
    def cluster(self) -> Cluster:
        """The live cluster — current availability over the declared
        topology.  A ``PlanCache`` wired with this controller as its
        ``membership_source`` reads this on every lookup."""
        return self.manager.cluster

    @property
    def epoch(self) -> int:
        return self.epochs[-1].epoch

    @property
    def leader(self) -> str | None:
        return self.manager.leader

    def membership_mask(self) -> tuple[bool, ...]:
        return tuple(bool(n.available) for n in self.manager.cluster.nodes)

    def membership_fingerprint(self) -> str:
        return membership_fingerprint(self.manager.cluster)

    def available_names(self) -> tuple[str, ...]:
        return tuple(n.name for n in self.manager.cluster.nodes
                     if n.available)

    def add_epoch_hook(self, hook: Callable[[MembershipEpoch], object]
                       ) -> Callable[[MembershipEpoch], object]:
        """Register an additional per-epoch callback (fired after
        ``on_epoch``, in registration order).  Unlike the single
        constructor callback this composes: the serving engine's EXPLORE
        re-entry and a ``SpeculativePrewarmer``'s next-departure
        speculation can both observe the same epoch.  Returns the hook so
        it can be used as a decorator."""
        self._epoch_hooks.append(hook)
        return hook

    # --------------------------------------------------------------- driving
    def advance(self, now: float) -> tuple[ChurnEvent, ...]:
        """Apply every unconsumed event with ``time <= now``.  All events
        applied by one call coalesce into at most one new epoch; the
        heartbeat monitor is beaten for every available node at ``now`` so
        ``refresh_availability`` agrees with the trace.  Returns the
        applied events (empty when nothing fired)."""
        applied: list[ChurnEvent] = []
        events = self.trace.events
        while self._cursor < len(events) and events[self._cursor].time <= now:
            e = events[self._cursor]
            self._cursor += 1
            self._apply(e)
            applied.append(e)
        self.now = max(self.now, now)
        if applied:
            self._close_epoch(applied)
        for name in self.available_names():
            self.manager.monitor.beat(name, self.now)
        return tuple(applied)

    def _apply(self, e: ChurnEvent) -> None:
        up = not e.goes_down
        self.manager.set_available(e.node, up)
        if not up and self.feedback is not None:
            # a departed node's half-filled drift windows must not judge
            # its post-return measurements
            self.feedback.forget_resource(e.node)

    def _close_epoch(self, applied: Iterable[ChurnEvent]) -> None:
        if not self.manager.leader_available():
            self._elect_fallback()
        mask = self.membership_mask()
        last = self.epochs[-1]
        if mask == last.mask:
            return                      # e.g. a leave+join that cancelled out
        ep = MembershipEpoch(epoch=last.epoch + 1, time=self.now, mask=mask,
                             fingerprint=self.membership_fingerprint(),
                             leader=self.manager.leader,
                             events=tuple(applied))
        self.epochs.append(ep)
        if self.telemetry is not None:
            self.telemetry.gauge(
                "fleet.membership", float(ep.available()), t=ep.time,
                epoch=ep.epoch, fingerprint=ep.fingerprint[:12],
                leader=ep.leader or "",
                events=",".join(e.kind for e in ep.events))
        if self.on_epoch is not None:
            self.on_epoch(ep)
        for hook in self._epoch_hooks:
            hook(ep)

    def _elect_fallback(self, count: bool = True) -> str | None:
        """Hand the seat over via the shared ``ensure_leader`` policy,
        counting the hand-off when it really changed hands."""
        before = self.manager.leader
        name = self.manager.ensure_leader()
        if count and name != before:
            self.leader_elections += 1
            if self.telemetry is not None:
                self.telemetry.counter(
                    "fleet.leader_election", t=self.now,
                    epoch=self.epochs[-1].epoch if self.epochs else 0,
                    previous=before or "", leader=name or "")
        return name

    def elect_leader(self, preferred: str | None = None) -> str:
        """Alg. 1 line 2 under churn: the preferred (receiving) node leads
        when available, otherwise the sitting leader or the first
        available node (``ClusterManager.ensure_leader`` — the one
        fail-over policy).  Raises when the fleet is empty."""
        name = self.manager.ensure_leader(preferred)
        if name is None:
            raise RuntimeError("no available node to lead")
        return name

    # --------------------------------------------------- fault-injection peek
    def next_failure(self, start: float, end: float,
                     nodes: Iterable[str]) -> ChurnEvent | None:
        """The earliest *unconsumed* failure event (``crash``) with
        ``start < time <= end`` on one of ``nodes`` — peeked, not applied.
        The simulator uses this to decide whether an execution window
        survives; handling the failure then goes through :meth:`advance`
        (which consumes everything up to the crash instant, coalescing it
        with any earlier graceful events into one epoch)."""
        targets = set(nodes)
        for e in self.trace.events[self._cursor:]:
            if e.time > end:
                break
            if e.time > start and e.is_failure and e.node in targets:
                return e
        return None

    def __repr__(self) -> str:
        return (f"FleetController(epoch={self.epoch}, "
                f"available={len(self.available_names())}/"
                f"{len(self.manager.cluster.nodes)}, "
                f"leader={self.manager.leader!r}, "
                f"events {self._cursor}/{len(self.trace)})")
