"""repro.fleet — churn-aware cluster dynamics.

The subsystem that makes the serving/simulation stack elastic: seeded,
replayable availability traces (:mod:`~repro.fleet.traces`), a
:class:`FleetController` that replays them into membership epochs over the
existing ``ClusterManager``/``HeartbeatMonitor`` machinery
(:mod:`~repro.fleet.controller`), and — through
``repro.core.fingerprint.membership_fingerprint`` — the hash that lets
``PlanCache`` file warm fronts for distinct memberships side by side, so a
node that leaves and returns re-serves its front with zero DP work.  See
docs/fleet.md for the lifecycle.
"""

from .controller import FleetController, MembershipEpoch  # noqa: F401
from .traces import (DOWN_KINDS, FAILURE_KINDS, KINDS,  # noqa: F401
                     UP_KINDS, ChurnEvent, ChurnTrace)
