"""Availability-event traces — the cluster dynamics a churn-aware fleet
replays.

HiDP's leader probes availability before every plan (Alg. 1 line 3,
Eq. 4); CoEdge and DEFER both treat device churn — nodes joining, leaving,
crashing, browning out, throttling — as the defining edge condition.  This
module turns those conditions into data: a :class:`ChurnTrace` is an
immutable, time-sorted sequence of :class:`ChurnEvent` s that a
:class:`~repro.fleet.controller.FleetController` applies to a live
``ClusterManager``.  Traces are *replayable*: the trace itself never
mutates (the controller keeps the cursor), so the same trace drives a
simulation, a benchmark gate, and a unit test to identical membership
histories.

Event kinds and their availability semantics (the controller's mapping):

========================  ======================================================
kind                      meaning
========================  ======================================================
``leave``                 graceful departure — α_j → 0 at the next planning
                          boundary; in-flight shards complete
``crash``                 hard failure — α_j → 0 *immediately*; shards running
                          on the node at that instant fail and their request
                          must re-plan on the survivors (the simulator's
                          mid-request fault-injection path)
``battery_drain``         the node's battery ran out — availability-wise a
                          graceful leave (duty-cycled fleets announce it)
``thermal_throttle``      the node capped itself below usable capacity —
                          treated as a graceful leave until it cools
``join``                  a (new or returning) node becomes available
``battery_ok``            recharged — the ``battery_drain`` twin of ``join``
``recover``               cooled down — the ``thermal_throttle`` twin
========================  ======================================================

Generators: :meth:`ChurnTrace.scripted` (exact schedules — the unit-test
workhorse), :meth:`ChurnTrace.poisson` (seeded memoryless churn with
plausibility tracking: only present nodes leave, only absent nodes join),
:meth:`ChurnTrace.battery` and :meth:`ChurnTrace.thermal` (deterministic
duty cycles).  Compose with :meth:`ChurnTrace.merge`.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Iterable, Iterator, Sequence

#: kinds that flip a node's availability to 0
DOWN_KINDS = frozenset({"leave", "crash", "battery_drain",
                        "thermal_throttle"})
#: kinds that flip a node's availability to 1
UP_KINDS = frozenset({"join", "battery_ok", "recover"})
#: kinds that fail in-flight shards (vs taking effect at a plan boundary)
FAILURE_KINDS = frozenset({"crash"})

KINDS = DOWN_KINDS | UP_KINDS


@dataclasses.dataclass(frozen=True, order=True)
class ChurnEvent:
    """One availability change: at ``time``, ``node`` undergoes ``kind``."""

    time: float
    node: str
    kind: str

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown churn kind {self.kind!r}; "
                             f"expected one of {sorted(KINDS)}")

    @property
    def goes_down(self) -> bool:
        return self.kind in DOWN_KINDS

    @property
    def is_failure(self) -> bool:
        """True for kinds that kill in-flight shards (``crash``)."""
        return self.kind in FAILURE_KINDS


@dataclasses.dataclass(frozen=True)
class ChurnTrace:
    """An immutable, time-sorted availability-event schedule."""

    events: tuple[ChurnEvent, ...] = ()

    def __post_init__(self) -> None:
        times = [e.time for e in self.events]
        if times != sorted(times):
            object.__setattr__(self, "events",
                               tuple(sorted(self.events)))

    # -------------------------------------------------------------- builders
    @classmethod
    def scripted(cls, events: Iterable[ChurnEvent | tuple[float, str, str]]
                 ) -> "ChurnTrace":
        """An exact schedule: ``(time, node, kind)`` tuples or events."""
        return cls(tuple(e if isinstance(e, ChurnEvent) else ChurnEvent(*e)
                         for e in events))

    @classmethod
    def poisson(cls, node_names: Sequence[str], rate: float, horizon: float,
                seed: int = 0, crash_fraction: float = 0.5,
                protect: Sequence[str] = ()) -> "ChurnTrace":
        """Memoryless churn: events arrive as a Poisson process at ``rate``
        events/second over ``[0, horizon)``.  Each event picks a node
        uniformly and stays *plausible* — a present node leaves (a crash
        with probability ``crash_fraction``, else gracefully) and an absent
        node rejoins.  ``protect`` names nodes the trace never touches
        (keep the leader's seat stable).  Seeded: the same
        ``(node_names, rate, horizon, seed)`` always replays the same
        trace."""
        if rate <= 0:
            return cls(())
        rng = random.Random(seed)
        pool = [n for n in node_names if n not in set(protect)]
        if not pool:
            return cls(())
        present = dict.fromkeys(pool, True)
        events: list[ChurnEvent] = []
        t = rng.expovariate(rate)
        while t < horizon:
            node = rng.choice(pool)
            if present[node]:
                kind = "crash" if rng.random() < crash_fraction else "leave"
                present[node] = False
            else:
                kind = "join"
                present[node] = True
            events.append(ChurnEvent(t, node, kind))
            t += rng.expovariate(rate)
        return cls(tuple(events))

    @classmethod
    def battery(cls, node_names: Sequence[str], drain_after: float,
                recharge_after: float, horizon: float,
                stagger: float = 0.0) -> "ChurnTrace":
        """Duty-cycled batteries: each node drains after ``drain_after``
        seconds up, recharges ``recharge_after`` seconds later, repeating
        until ``horizon``.  ``stagger`` offsets successive nodes' cycles so
        the whole fleet never browns out at once."""
        return cls._duty_cycle(node_names, drain_after, recharge_after,
                               horizon, stagger, "battery_drain",
                               "battery_ok")

    @classmethod
    def thermal(cls, node_names: Sequence[str], throttle_after: float,
                cool_after: float, horizon: float,
                stagger: float = 0.0) -> "ChurnTrace":
        """Thermal duty cycle: sustained load trips the governor after
        ``throttle_after`` seconds; the node recovers ``cool_after``
        seconds later."""
        return cls._duty_cycle(node_names, throttle_after, cool_after,
                               horizon, stagger, "thermal_throttle",
                               "recover")

    @classmethod
    def _duty_cycle(cls, node_names: Sequence[str], up_s: float,
                    down_s: float, horizon: float, stagger: float,
                    down_kind: str, up_kind: str) -> "ChurnTrace":
        if up_s <= 0 or down_s <= 0:
            raise ValueError("duty-cycle phases must be positive")
        events: list[ChurnEvent] = []
        for i, name in enumerate(node_names):
            t = i * stagger + up_s
            while t < horizon:
                events.append(ChurnEvent(t, name, down_kind))
                if t + down_s >= horizon:
                    break
                events.append(ChurnEvent(t + down_s, name, up_kind))
                t += down_s + up_s
        return cls(tuple(sorted(events)))

    # ------------------------------------------------------------- operators
    def merge(self, *others: "ChurnTrace") -> "ChurnTrace":
        """The union schedule, time-sorted (ties keep left-operand order)."""
        merged = list(self.events)
        for o in others:
            merged.extend(o.events)
        return ChurnTrace(tuple(sorted(merged, key=lambda e: e.time)))

    def window(self, t0: float, t1: float) -> tuple[ChurnEvent, ...]:
        """Events with ``t0 < time <= t1`` (the half-open advance window)."""
        return tuple(e for e in self.events if t0 < e.time <= t1)

    def __iter__(self) -> Iterator[ChurnEvent]:
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:
        if not self.events:
            return "ChurnTrace(empty)"
        return (f"ChurnTrace({len(self.events)} events, "
                f"t [{self.events[0].time:.3g}, "
                f"{self.events[-1].time:.3g}] s)")
