"""Pure-jnp oracles for every kernel in this package.

Three tiers per op:
  * ``*_naive``   — direct einsum/softmax math; the correctness oracle.
  * ``*_blocked`` — the flash/chunked algorithm written in pure jnp
                    (lax.scan over blocks, online softmax / chunked state
                    passing).  Numerically equivalent to naive; used as the
                    default lowering path on CPU dry-runs because it has the
                    kernel's memory profile without requiring Pallas.
  * Pallas kernels in sibling modules are validated against these in
    ``tests/test_kernels.py`` over shape/dtype sweeps.

Shape conventions (throughout the repo):
  q: (B, Tq, Hq, D)   k/v: (B, Tk, Hkv, D)   with Hq % Hkv == 0 (GQA).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_expand(q: jax.Array, n_kv: int) -> jax.Array:
    """(B,T,Hq,D) → (B,T,Hkv,G,D) grouped view for GQA einsums."""
    b, t, hq, d = q.shape
    return q.reshape(b, t, n_kv, hq // n_kv, d)


# --------------------------------------------------------------------------
# Attention — naive oracle
# --------------------------------------------------------------------------

def attention_naive(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    q_offset: int = 0,
                    lengths: jax.Array | None = None) -> jax.Array:
    """Full-materialisation attention.  ``q_offset`` is the absolute position
    of q[0] (for decode/chunked prefill); ``lengths`` (B,) masks the KV
    suffix (serving: per-sequence fill level)."""
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    qg = _gqa_expand(q, hkv)
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(tq)[:, None]            # (tq,1)
    kpos = jnp.arange(tk)[None, :]                       # (1,tk)
    mask = jnp.ones((tq, tk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if lengths is not None:
        mask = mask[None] & (kpos[None] < lengths[:, None, None])
        mask = mask[:, None, None]                       # (b,1,1,tq,tk)
    else:
        mask = mask[None, None, None]
    s = jnp.where(mask, s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(s - m), 0.0)             # 0 on masked rows
    l = p.sum(-1, keepdims=True)
    p = p / jnp.maximum(l, 1e-30)                        # fully-masked row → 0
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, tq, hq, d).astype(q.dtype)


# --------------------------------------------------------------------------
# Attention — blocked flash (online softmax), pure jnp
# --------------------------------------------------------------------------

def attention_blocked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int | None = None,
                      q_offset: int = 0,
                      lengths: jax.Array | None = None,
                      block_q: int = 512, block_k: int = 512) -> jax.Array:
    """Flash algorithm in jnp: scan over q blocks (outer) and kv blocks
    (inner) with running (m, l, acc).  Never materialises Tq×Tk."""
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    g = hq // hkv
    bq = min(block_q, tq)
    bk = min(block_k, tk)
    nq, nk = -(-tq // bq), -(-tk // bk)
    pad_q, pad_k = nq * bq - tq, nk * bk - tk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(d)
    qb = q.reshape(b, nq, bq, hkv, g, d).astype(jnp.float32)
    kb = k.reshape(b, nk, bk, hkv, d).astype(jnp.float32)
    vb = v.reshape(b, nk, bk, hkv, d).astype(jnp.float32)

    kpos_all = jnp.arange(nk * bk)
    klen = lengths if lengths is not None else jnp.full((b,), tk)

    # The q-block body is checkpointed: without it, reverse-mode AD stores
    # every (bq, bk) probability panel (O(T²) memory — 6+ GB/layer at 4k×12k);
    # with it, backward recomputes the panels flash-style.
    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def q_block(qi, qblk):
        qpos = q_offset + qi * bq + jnp.arange(bq)      # (bq,)

        def kv_step(carry, inp):
            m, l, acc = carry
            kblk, vblk, kpos = inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk) * scale
            msk = jnp.ones((bq, bk), dtype=bool)
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                msk &= kpos[None, :] > qpos[:, None] - window
            msk = msk[None] & (kpos[None, None, :] < klen[:, None, None])
            msk = msk[:, None, None]
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.where(msk, jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vblk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, bq), NEG_INF)
        l0 = jnp.zeros((b, hkv, g, bq))
        a0 = jnp.zeros((b, hkv, g, bq, d))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1),
             kpos_all.reshape(nk, bk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # (b,hkv,g,bq,d)
        return out.transpose(0, 3, 1, 2, 4)              # (b,bq,hkv,g,d)

    outs = jax.lax.map(lambda i: q_block(i, qb[:, i]), jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * bq, hq, d)
    return out[:, :tq].astype(q.dtype)


# --------------------------------------------------------------------------
# Decode attention — single new token against a filled KV cache
# --------------------------------------------------------------------------

def decode_attention_naive(q: jax.Array, k_cache: jax.Array,
                           v_cache: jax.Array, lengths: jax.Array, *,
                           window: int | None = None) -> jax.Array:
    """q: (B, 1, Hq, D); caches: (B, S, Hkv, D); lengths: (B,) — number of
    valid cache entries (the new token's position is lengths-1)."""
    b, _, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    qg = _gqa_expand(q, hkv)[:, 0]                       # (b,hkv,g,d)
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    kpos = jnp.arange(s)[None, :]
    msk = kpos < lengths[:, None]
    if window is not None:
        msk &= kpos >= (lengths[:, None] - window)
    msk = msk[:, None, None]
    scores = jnp.where(msk, scores, NEG_INF)
    m = scores.max(-1, keepdims=True)
    p = jnp.where(msk, jnp.exp(scores - m), 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, hq, d).astype(q.dtype)


# --------------------------------------------------------------------------
# Mamba-2 SSD — naive recurrence oracle and the chunked (SSD) algorithm
# --------------------------------------------------------------------------

def ssd_naive(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
              C: jax.Array, D: jax.Array,
              h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Sequential SSM recurrence (the oracle).

    x: (b, t, nh, hd)   dt: (b, t, nh)   A: (nh,) (negative)
    B, C: (b, t, n)     D: (nh,)         h0: (b, nh, hd, n)
    Returns y (b, t, nh, hd), final state (b, nh, hd, n).
    """
    b, t, nh, hd = x.shape
    n = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, n), dtype=jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp                            # (b,nh,hd),(b,nh),(b,n),(b,n)
        dA = jnp.exp(dtt * A[None, :])                   # (b,nh)
        dBx = jnp.einsum("bn,bhp->bhpn", Bt, xt * dtt[..., None])
        h = h * dA[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", h, Ct)
        return h, y

    xs = (x.astype(jnp.float32).swapaxes(0, 1), dt.swapaxes(0, 1),
          B.astype(jnp.float32).swapaxes(0, 1),
          C.astype(jnp.float32).swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1) + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h


def _segsum(logs: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum(logs[..., j+1:i+1]) for j<=i,
    -inf otherwise (the 1-semiseparable mask of the SSD paper)."""
    t = logs.shape[-1]
    cs = jnp.cumsum(logs, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, D: jax.Array, *, chunk: int = 128,
                h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """State-space duality algorithm (Mamba-2 §6): quadratic attention-like
    compute inside chunks + linear state recurrence across chunks."""
    b, t, nh, hd = x.shape
    n = B.shape[-1]
    c = min(chunk, t)
    nc = -(-t // c)
    pad = nc * c - t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    xf = x.astype(jnp.float32).reshape(b, nc, c, nh, hd)
    dtf = dt.astype(jnp.float32).reshape(b, nc, c, nh)
    Bf = B.astype(jnp.float32).reshape(b, nc, c, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, c, n)

    dA = dtf * A[None, None, None, :]                    # (b,nc,c,nh) log-decay
    dA_cs = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum
    # 1. intra-chunk (quadratic, the "attention-like" part)
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))       # (b,nc,nh,i,j)
    scores = jnp.einsum("bzin,bzjn->bzij", Cf, Bf)       # (b,nc,i,j)
    xdt = xf * dtf[..., None]                            # x̄ = x·dt
    y_diag = jnp.einsum("bzij,bzhij,bzjhp->bzihp", scores, L, xdt)
    # 2. per-chunk final states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (b,nc,c,nh)
    states = jnp.einsum("bzcn,bzch,bzchp->bzhpn", Bf,
                        decay_states, xdt)
    # 3. inter-chunk recurrence
    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, n), dtype=jnp.float32)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])            # (b,nc,nh)

    def chunk_step(h, inp):
        st, dec = inp                                    # (b,nh,hd,n),(b,nh)
        h_new = h * dec[..., None, None] + st
        return h_new, h                                  # emit state ENTERING chunk

    (h_final, h_in) = jax.lax.scan(
        chunk_step, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_in = h_in.swapaxes(0, 1)                           # (b,nc,nh,hd,n)
    # 4. chunk-input contribution
    in_decay = jnp.exp(dA_cs)                            # (b,nc,c,nh)
    y_off = jnp.einsum("bzcn,bzch,bzhpn->bzchp", Cf, in_decay, h_in)
    y = (y_diag + y_off).reshape(b, nc * c, nh, hd)[:, :t]
    y = y + x.astype(jnp.float32)[:, :t] * D[None, None, :, None]
    return y.astype(x.dtype), h_final


def ssd_decode_step(h: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
                    B: jax.Array, C: jax.Array, D: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """One-token SSM update.  h: (b,nh,hd,n); x: (b,nh,hd); dt: (b,nh);
    B,C: (b,n).  Returns (y (b,nh,hd), h_new)."""
    dA = jnp.exp(dt * A[None, :])
    dBx = jnp.einsum("bn,bhp->bhpn", B.astype(jnp.float32),
                     x.astype(jnp.float32) * dt[..., None])
    h_new = h * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", h_new, C.astype(jnp.float32))
    y = y + x.astype(jnp.float32) * D[None, :, None]
    return y.astype(x.dtype), h_new
