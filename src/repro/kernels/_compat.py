"""jax version compatibility shims for the Pallas TPU kernels.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in newer
jax releases; resolve whichever this environment ships so the kernels (and
their interpret-mode tests) run on both sides of the rename.
"""

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:          # pragma: no cover - very old jax
    raise ImportError("no Pallas TPU CompilerParams class in this jax")
