"""Jit'd dispatch wrappers around the kernels.

``set_backend()`` / the ``REPRO_KERNEL_BACKEND`` env var select the lowering:

  * ``pallas``   — the Pallas TPU kernels (``interpret=True`` automatically on
                   CPU so tests can run anywhere).
  * ``blocked``  — pure-jnp flash/chunked algorithms (ref.py).  Default for
                   dry-runs: same memory profile as the kernels, lowers on any
                   backend, keeps HLO clean for cost analysis.
  * ``naive``    — full-materialisation oracles (tiny shapes/tests only).

Models call only these entry points, so the backend choice is a launcher
concern (the TPU launcher sets ``pallas``; dry-run and CI set ``blocked``).
"""

from __future__ import annotations

import functools
import os
from typing import Literal

import jax

from . import ref

Backend = Literal["pallas", "blocked", "naive"]
_BACKEND: Backend = os.environ.get("REPRO_KERNEL_BACKEND", "blocked")  # type: ignore


def set_backend(backend: Backend) -> None:
    global _BACKEND
    if backend not in ("pallas", "blocked", "naive"):
        raise ValueError(backend)
    _BACKEND = backend


def get_backend() -> Backend:
    return _BACKEND


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# --------------------------------------------------------------------------
# Attention (prefill / training)
# --------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                    lengths=None, block_q=512, block_k=512):
    if _BACKEND == "naive":
        return ref.attention_naive(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, lengths=lengths)
    if _BACKEND == "pallas":
        from . import flash_attention as fa
        return fa.flash_attention(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset, lengths=lengths,
                                  block_q=block_q, block_k=block_k,
                                  interpret=not _on_tpu())
    return ref.attention_blocked(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, lengths=lengths,
                                 block_q=block_q, block_k=block_k)


# --------------------------------------------------------------------------
# Decode attention (one token vs. KV cache)
# --------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, lengths, *, window=None,
                     block_k=1024):
    if _BACKEND == "pallas":
        from . import decode_attention as da
        return da.decode_attention(q, k_cache, v_cache, lengths,
                                   window=window, block_k=block_k,
                                   interpret=not _on_tpu())
    return ref.decode_attention_naive(q, k_cache, v_cache, lengths,
                                      window=window)


# --------------------------------------------------------------------------
# Mamba-2 SSD
# --------------------------------------------------------------------------

def ssd(x, dt, A, B, C, D, *, chunk=128, h0=None):
    """Chunked SSD scan (prefill/training)."""
    if _BACKEND == "naive":
        return ref.ssd_naive(x, dt, A, B, C, D, h0=h0)
    if _BACKEND == "pallas":
        from . import ssd_scan
        return ssd_scan.ssd(x, dt, A, B, C, D, chunk=chunk, h0=h0,
                            interpret=not _on_tpu())
    return ref.ssd_chunked(x, dt, A, B, C, D, chunk=chunk, h0=h0)


def ssd_decode_step(h, x, dt, A, B, C, D):
    return ref.ssd_decode_step(h, x, dt, A, B, C, D)
