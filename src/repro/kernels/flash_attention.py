"""Pallas TPU flash-attention (prefill/training) kernel.

TPU-native design (DESIGN.md §2 — adapted from the GPU flash algorithm):

* Grid = (batch, q_heads, q_blocks, kv_blocks); the kv dimension is the
  innermost ("arbitrary") axis so the online-softmax state lives in VMEM
  scratch across kv steps; batch/head/q axes are parallel (Megacore-safe).
* BlockSpecs tile HBM→VMEM: q/out blocks are (block_q, head_dim), k/v blocks
  (block_k, head_dim); with the default 512×512 bf16 tiles the working set is
  ~1.3 MB — far under the ~16 MB v5e VMEM budget, leaving room for double
  buffering; matmul dims are multiples of 128 to keep the MXU systolic array
  full (head_dim 64/128/256 all align).
* GQA is folded into the k/v index_map (q head h reads kv head h // group) —
  no KV replication in HBM.
* Causality and sliding windows prune whole kv blocks via ``pl.when`` — the
  TPU analogue of the GPU kernel's early-exit, saving real FLOPs, not just
  masking.  ``lengths`` (ragged batches) and ``window`` arrive as
  scalar-prefetch operands so one compiled kernel serves every layer of a
  local:global schedule (gemma3) — window is data, not code.

Validated against ref.attention_naive in tests/test_kernels.py with
interpret=True shape/dtype sweeps.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

NEG_INF = -1e30


def _kernel(lengths_ref, window_ref,            # scalar prefetch
            q_ref, k_ref, v_ref,                # VMEM inputs
            o_ref,                              # VMEM output
            m_ref, l_ref, acc_ref,              # VMEM scratch
            *, causal: bool, block_q: int, block_k: int, q_offset: int,
            scale: float, num_kv_blocks: int):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    window = window_ref[0]
    length = lengths_ref[b]
    q_lo = q_offset + iq * block_q                   # first absolute q pos
    q_hi = q_lo + block_q - 1
    k_lo = ik * block_k
    k_hi = k_lo + block_k - 1

    run = k_lo < length                              # block has valid keys
    if causal:
        run &= k_lo <= q_hi                          # not fully above diag
    run &= k_hi > q_lo - window                      # not fully out-of-window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k),
                                               0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k),
                                               1)
        msk = kpos < length
        if causal:
            msk &= kpos <= qpos
        msk &= kpos > qpos - window
        s = jnp.where(msk, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(msk, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | jax.Array | None = None,
                    q_offset: int = 0, lengths: jax.Array | None = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Tq, Hq, D); k/v: (B, Tk, Hkv, D).  Returns (B, Tq, Hq, D)."""
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    g = hq // hkv
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    nq = -(-tq // block_q)
    nk = -(-tk // block_k)
    pad_q, pad_k = nq * block_q - tq, nk * block_k - tk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    # (B, H, T, D) layout: head-major so a (1,1,bq,d) block is contiguous.
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    if lengths is None:
        lengths = jnp.full((b,), tk, jnp.int32)
    if window is None:
        window = jnp.array([2 ** 30], jnp.int32)
    else:
        window = jnp.asarray(window, jnp.int32).reshape(1)

    kernel = functools.partial(
        _kernel, causal=causal, block_q=block_q, block_k=block_k,
        q_offset=q_offset, scale=1.0 / math.sqrt(d), num_kv_blocks=nk)

    grid = (b, hq, nq, nk)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, block_q, d),
                             lambda b, h, iq, ik, *_: (b, h, iq, 0)),
                pl.BlockSpec((1, 1, block_k, d),
                             lambda b, h, iq, ik, *_: (b, h // g, ik, 0)),
                pl.BlockSpec((1, 1, block_k, d),
                             lambda b, h, iq, ik, *_: (b, h // g, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, block_q, d),
                                   lambda b, h, iq, ik, *_: (b, h, iq, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), window, qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :tq]
