"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk pass.

The SSD algorithm splits into (a) a quadratic attention-like pass inside each
chunk and (b) a linear recurrence across chunk states.  (a) carries ~all the
FLOPs and maps onto the MXU; (b) is a tiny (nh, hd, n) scan that stays in
plain XLA (ops wrapper) — forcing it into the kernel would serialise the
grid for no compute win.  This split is the TPU adaptation of the fused GPU
kernel in the Mamba-2 release (DESIGN.md §2).

Kernel, per (batch, chunk) grid cell — all heads processed together so the
(c, n) B/C panels are loaded once per chunk:

  scores = C · Bᵀ                (c×c, MXU)
  L      = exp(segsum(dA))       per head (nh, c, c)
  y_diag = (scores ⊙ L_h) · x̄_h  batched over heads (MXU)
  states = (B ⊙ decay)ᵀ · x̄_h    per-chunk outgoing state (nh, n, hd)

VMEM at c=128, nh=48, hd=64, n=128: x̄ 1.5 MB + L 3.1 MB + panels < 6 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from ._compat import CompilerParams


def _kernel(xdt_ref, dacs_ref, b_ref, c_ref,
            ydiag_ref, states_ref,
            *, nh: int, hd: int, n: int, chunk: int):
    xdt = xdt_ref[0, 0].astype(jnp.float32)          # (c, nh*hd)
    dacs = dacs_ref[0, 0].astype(jnp.float32)        # (c, nh) cumsum log-decay
    B = b_ref[0, 0].astype(jnp.float32)              # (c, n)
    C = c_ref[0, 0].astype(jnp.float32)              # (c, n)

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))  # (c,c)
    # L[h,i,j] = exp(dacs[i,h] - dacs[j,h]) masked to j<=i
    di = dacs.T[:, :, None]                          # (nh, c, 1)
    dj = dacs.T[:, None, :]                          # (nh, 1, c)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tril = (jj <= ii)[None]
    L = jnp.where(tril, jnp.exp(di - dj), 0.0)       # (nh, c, c)
    w = scores[None] * L                             # (nh, c, c)
    xh = xdt.reshape(chunk, nh, hd).transpose(1, 0, 2)   # (nh, c, hd)
    y = jax.lax.dot_general(w, xh, (((2,), (1,)), ((0,), (0,))))  # (nh,c,hd)
    ydiag_ref[0, 0] = y.transpose(1, 0, 2).reshape(
        chunk, nh * hd).astype(ydiag_ref.dtype)

    # outgoing chunk state: states[h] = Σ_j exp(dacs[-1,h]-dacs[j,h]) B_j x̄_jh
    decay = jnp.exp(dacs[-1][None, :] - dacs)        # (c, nh)
    bd = B[:, None, :] * decay[:, :, None]           # (c, nh, n)
    bd = bd.transpose(1, 2, 0)                       # (nh, n, c)
    st = jax.lax.dot_general(bd, xh, (((2,), (1,)), ((0,), (0,))))  # (nh,n,hd)
    states_ref[0, 0] = st.astype(states_ref.dtype)


def ssd_intra_chunk(xdt: jax.Array, dacs: jax.Array, B: jax.Array,
                    C: jax.Array, *, nh: int, hd: int,
                    interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """xdt: (b, nc, c, nh*hd)  dacs: (b, nc, c, nh)  B/C: (b, nc, c, n).
    Returns (y_diag (b, nc, c, nh*hd), states (b, nc, nh, n, hd))."""
    b, nc, c, _ = xdt.shape
    n = B.shape[-1]
    kernel = functools.partial(_kernel, nh=nh, hd=hd, n=n, chunk=c)
    y, st = pl.pallas_call(
        kernel,
        grid=(b, nc),
        in_specs=[
            pl.BlockSpec((1, 1, c, nh * hd), lambda b, z: (b, z, 0, 0)),
            pl.BlockSpec((1, 1, c, nh), lambda b, z: (b, z, 0, 0)),
            pl.BlockSpec((1, 1, c, n), lambda b, z: (b, z, 0, 0)),
            pl.BlockSpec((1, 1, c, n), lambda b, z: (b, z, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, nh * hd), lambda b, z: (b, z, 0, 0)),
            pl.BlockSpec((1, 1, nh, n, hd), lambda b, z: (b, z, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, c, nh * hd), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, nh, n, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xdt, dacs, B, C)
    return y, st


def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
        C: jax.Array, D: jax.Array, *, chunk: int = 128,
        h0: jax.Array | None = None,
        interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Drop-in replacement for ref.ssd_chunked with the quadratic pass in
    Pallas.  Shapes as in ref.py."""
    b, t, nh, hd = x.shape
    n = B.shape[-1]
    c = min(chunk, t)
    nc = -(-t // c)
    pad = nc * c - t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    xf = x.astype(jnp.float32).reshape(b, nc, c, nh, hd)
    dtf = dt.astype(jnp.float32).reshape(b, nc, c, nh)
    Bf = B.astype(jnp.float32).reshape(b, nc, c, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, c, n)
    dA = dtf * A[None, None, None, :]
    dA_cs = jnp.cumsum(dA, axis=2)
    xdt = (xf * dtf[..., None]).reshape(b, nc, c, nh * hd)

    y_diag, states = ssd_intra_chunk(xdt, dA_cs, Bf, Cf, nh=nh, hd=hd,
                                     interpret=interpret)
    states = states.transpose(0, 1, 2, 4, 3)          # (b, nc, nh, hd, n)

    # inter-chunk recurrence (tiny, stays in XLA)
    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, n), jnp.float32)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])         # (b, nc, nh)

    def step(h, inp):
        st, dec = inp
        return h * dec[..., None, None] + st, h
    h_final, h_in = jax.lax.scan(
        step, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_in = h_in.swapaxes(0, 1)                        # (b, nc, nh, hd, n)

    in_decay = jnp.exp(dA_cs)                         # (b, nc, c, nh)
    y_off = jnp.einsum("bzcn,bzch,bzhpn->bzchp", Cf, in_decay, h_in)
    y = y_diag.reshape(b, nc, c, nh, hd) + y_off
    y = y.reshape(b, nc * c, nh, hd)[:, :t]
    y = y + x.astype(jnp.float32)[:, :t] * D[None, None, :, None]
    return y.astype(x.dtype), h_final
