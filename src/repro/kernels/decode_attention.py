"""Pallas TPU flash-decode kernel: one new token per sequence against a large
KV cache.

Decode attention is memory-bound (arithmetic intensity ≈ 2 flops/byte of
cache), so the kernel is organised around streaming the cache through VMEM
exactly once:

* Grid = (batch, kv_heads, kv_blocks); kv innermost ("arbitrary") with the
  online-softmax state in VMEM scratch.
* The whole GQA query group (G = Hq/Hkv queries) rides along each kv head —
  the (G, block_k) score panel keeps the MXU busy while the cache streams.
* ``lengths`` (cache fill levels) and ``window`` are scalar-prefetch
  operands; fully-invalid blocks (beyond length, or before the window) are
  pruned with ``pl.when`` so a 1-token decode over a 32k cache with a 1k
  window reads ~1k keys, not 32k.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

NEG_INF = -1e30


def _kernel(lengths_ref, window_ref,
            q_ref, k_ref, v_ref,
            o_ref,
            m_ref, l_ref, acc_ref,
            *, block_k: int, num_kv_blocks: int, scale: float):
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    window = window_ref[0]
    k_lo = ik * block_k
    k_hi = k_lo + block_k - 1
    run = (k_lo < length) & (k_hi >= length - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        msk = (kpos < length) & (kpos >= length - window)
        s = jnp.where(msk, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(msk, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *,
                     window: int | jax.Array | None = None,
                     block_k: int = 1024,
                     interpret: bool = False) -> jax.Array:
    """q: (B, 1, Hq, D); caches: (B, S, Hkv, D); lengths: (B,).
    Returns (B, 1, Hq, D)."""
    b, one, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    g = hq // hkv
    block_k = min(block_k, s)
    nk = -(-s // block_k)
    pad_k = nk * block_k - s
    kt = k_cache.transpose(0, 2, 1, 3)               # (B, Hkv, S, D)
    vt = v_cache.transpose(0, 2, 1, 3)
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    qg = q[:, 0].reshape(b, hkv, g, d)               # (B, Hkv, G, D)

    if window is None:
        window = jnp.array([2 ** 30], jnp.int32)
    else:
        window = jnp.asarray(window, jnp.int32).reshape(1)

    kernel = functools.partial(_kernel, block_k=block_k, num_kv_blocks=nk,
                               scale=1.0 / math.sqrt(d))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, hkv, nk),
            in_specs=[
                pl.BlockSpec((1, 1, g, d), lambda b, h, ik, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_k, d),
                             lambda b, h, ik, *_: (b, h, ik, 0)),
                pl.BlockSpec((1, 1, block_k, d),
                             lambda b, h, ik, *_: (b, h, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda b, h, ik, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), window, qg, kt, vt)
    return out.reshape(b, 1, hq, d)