"""AdamW with cosine / WSD schedules — pure-JAX, sharding-transparent
(optimizer state mirrors parameter sharding leaf-for-leaf).

WSD (warmup-stable-decay) is the MiniCPM schedule; configs mark themselves
via ``LR_SCHEDULE = "wsd"`` (configs/minicpm_2b.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"          # "cosine" | "wsd" | "constant"
    decay_fraction: float = 0.1       # WSD: last 10% of steps decay
    state_dtype: str = "float32"      # "float32" | "bfloat16" (memory-bound)


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    # fp32 master copies when params are stored bf16 (halves gradient /
    # fsdp collective bytes; the optimizer updates the master and writes
    # back a bf16 cast)
    master: Any = None


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    if cfg.schedule == "wsd":
        decay_start = cfg.total_steps * (1.0 - cfg.decay_fraction)
        frac = jnp.clip((step - decay_start)
                        / jnp.maximum(cfg.total_steps - decay_start, 1),
                        0.0, 1.0)
        # exponential-style decay to 10% as in MiniCPM
        return cfg.lr * warm * jnp.where(step < decay_start, 1.0,
                                         0.1 ** frac)
    # cosine
    prog = jnp.clip(step / jnp.maximum(cfg.total_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def init(params: Any, state_dtype=jnp.float32,
         master: bool = False) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, state_dtype), params)
    mw = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
          if master else None)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros), master=mw)


def init_abstract(param_specs: Any, state_dtype=jnp.float32,
                  master: bool = False) -> OptState:
    z = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, state_dtype),
                     param_specs)
    mw = (jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                       param_specs) if master else None)
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32), m=z, v=z,
                    master=mw)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: OptConfig, params: Any, grads: Any,
                  state: OptState) -> tuple[Any, OptState, dict]:
    """One AdamW step with global-norm clipping.  Returns (params, state,
    metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    sd = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32

    def upd(p, g, m, v, mw):
        m = (cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g).astype(sd)
        v = (cfg.b2 * v.astype(jnp.float32)
             + (1 - cfg.b2) * g * g).astype(sd)
        mh, vh = m.astype(jnp.float32) / b1c, v.astype(jnp.float32) / b2c
        ref = mw if mw is not None else p
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * ref.astype(jnp.float32)
        new_ref = ref.astype(jnp.float32) - lr * delta
        if mw is not None:
            return new_ref.astype(p.dtype), m, v, new_ref
        return new_ref.astype(p.dtype), m, v, None

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_mw = (jax.tree.leaves(state.master) if state.master is not None
               else [None] * len(flat_p))
    new = [upd(p, g, m, v, mw) for p, g, m, v, mw in
           zip(flat_p, flat_g, flat_m, flat_v, flat_mw)]
    params = jax.tree.unflatten(tdef, [n[0] for n in new])
    m = jax.tree.unflatten(tdef, [n[1] for n in new])
    v = jax.tree.unflatten(tdef, [n[2] for n in new])
    master = (jax.tree.unflatten(tdef, [n[3] for n in new])
              if state.master is not None else None)
    return params, OptState(step=step, m=m, v=v, master=master), \
        {"grad_norm": gnorm, "lr": lr}
