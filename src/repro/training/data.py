"""Deterministic synthetic data pipeline.

Generates seeded token streams with enough structure that the CE loss
actually decreases (repeated n-gram motifs + a skewed unigram distribution),
so the end-to-end training example demonstrably learns.  Batches are yielded
as the exact dict the model's ``input_specs`` promises.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.models.config import ArchConfig


@dataclasses.dataclass
class SyntheticDataset:
    cfg: ArchConfig
    batch: int
    seq_len: int
    seed: int = 0

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed)
        v = self.cfg.vocab
        # skewed unigram distribution + a bank of motifs to memorise
        probs = 1.0 / np.arange(1, min(v, 4096) + 1) ** 1.1
        probs /= probs.sum()
        motifs = [rng.integers(0, min(v, 4096), size=8) for _ in range(32)]
        while True:
            seq = rng.choice(min(v, 4096), size=(self.batch,
                                                 self.seq_len + 1), p=probs)
            # splice motifs in (predictable continuations)
            for b in range(self.batch):
                for _ in range(self.seq_len // 32):
                    m = motifs[rng.integers(0, len(motifs))]
                    pos = rng.integers(0, self.seq_len - len(m))
                    seq[b, pos:pos + len(m)] = m
            batch = {"tokens": seq[:, :-1].astype(np.int32),
                     "targets": seq[:, 1:].astype(np.int32)}
            if self.cfg.family == "audio":
                batch["frames"] = rng.standard_normal(
                    (self.batch, self.seq_len // 2, self.cfg.d_model)
                ).astype(np.float32) * 0.1
            if self.cfg.family == "vlm":
                batch["vision"] = rng.standard_normal(
                    (self.batch, self.cfg.n_vision_tokens, self.cfg.d_model)
                ).astype(np.float32) * 0.1
            yield batch
