"""Training step construction: CE loss, microbatched gradient accumulation,
remat — all knobs driven by the HiDP ShardingPlan.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.sharding.plan import ShardingPlan
from . import optimizer as optim


def ce_loss(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Next-token cross entropy, mean over all positions.
    logits: (B, T, V) fp32; targets: (B, T) — already shifted by the data
    pipeline (targets[t] is the token after position t).

    SPMD note: the gold logit is extracted with a one-hot contraction, not a
    gather — a gather over a vocab-sharded tensor forces XLA to all-gather
    the full logits (TB-scale at 1M tokens × 256k vocab); the contraction
    partitions cleanly (partial sums + psum)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("btv,btv->bt", logits, onehot)
    return (logz - gold).mean()


def chunked_ce_loss(model: Model, params: dict, hidden: jax.Array,
                    targets: jax.Array, chunks: int) -> jax.Array:
    """CE computed in sequence slices so the fp32 logits working set is
    (B, T/chunks, V) instead of (B, T, V) — at 1M tokens × 256k vocab that is
    the difference between ~0.5 GB and ~17 GB per device.  The chunk body is
    checkpointed: backward recomputes each slice's logits instead of storing
    them."""
    from repro.sharding import ctx as shard_ctx
    b, t, d = hidden.shape
    chunks = min(chunks, t)
    while t % chunks:
        chunks -= 1
    hs = hidden.reshape(b, chunks, t // chunks, d).swapaxes(0, 1)
    ts = targets.reshape(b, chunks, t // chunks).swapaxes(0, 1)

    @jax.checkpoint
    def body(acc, xs):
        h, tg = xs
        logits = shard_ctx.constrain_logits(model.unembed_hidden(params, h))
        logz = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(tg, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.einsum("btv,btv->bt", logits, onehot)
        return acc + (logz - gold).sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ts))
    return total / (b * t)


def loss_fn(model: Model, params: dict, batch: dict, *,
            remat: bool = True, moe_impl: str = "dense",
            remat_group: int = 1, loss_chunks: int = 8) -> jax.Array:
    if loss_chunks > 1:
        hidden = model.apply_train(params, batch, remat=remat,
                                   remat_group=remat_group,
                                   moe_impl=moe_impl, return_hidden=True)
        return chunked_ce_loss(model, params, hidden, batch["targets"],
                               loss_chunks)
    logits = model.apply_train(params, batch, remat=remat,
                               remat_group=remat_group, moe_impl=moe_impl)
    return ce_loss(logits, batch["targets"])


def _split_microbatches(batch: dict, n: int) -> dict:
    """(B, ...) → (n, B/n, ...)."""
    return {k: v.reshape((n, v.shape[0] // n) + v.shape[1:])
            for k, v in batch.items()}


def make_train_step(model: Model, opt_cfg: optim.OptConfig,
                    plan: ShardingPlan) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  Microbatch count and remat policy come from the HiDP plan."""
    n_micro = max(plan.microbatches, 1)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(model, p, batch, remat=plan.remat,
                              remat_group=getattr(plan, "remat_group", 1),
                              moe_impl=plan.moe_impl))(params)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = _split_microbatches(batch, n_micro)

            def acc_step(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grads_of(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (loss_acc + loss, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros(()), zeros), micro)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)
        params, opt_state, metrics = optim.apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
