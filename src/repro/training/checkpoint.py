"""Checkpointing: msgpack-serialised pytrees with atomic rename, step
tagging, and resume — the fault-tolerance substrate (restart after node
failure re-enters the run at the last durable step).
"""

from __future__ import annotations

import os
import struct
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x) -> dict:
    arr = np.asarray(x)
    # msgpack has no bf16: view as uint16 and tag the true dtype
    tag = str(x.dtype) if hasattr(x, "dtype") else str(arr.dtype)
    if tag == "bfloat16":
        arr = arr.view(np.uint16)
    return {"dtype": tag, "shape": list(arr.shape),
            "data": arr.tobytes()}


def _unpack_leaf(d: dict):
    dtype = d["dtype"]
    if dtype == "bfloat16":
        arr = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return jnp.asarray(arr).view(jnp.bfloat16)
    arr = np.frombuffer(d["data"], np.dtype(dtype)).reshape(d["shape"])
    return jnp.asarray(arr)


def save(path: str, tree: Any, step: int) -> str:
    """Atomic write of {step, tree} → ``path`` (tmp + rename)."""
    leaves, treedef = jax.tree.flatten(tree)
    payload = {"step": step,
               "leaves": [_pack_leaf(l) for l in leaves]}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)           # atomic on POSIX
    return path


def restore(path: str, like: Any) -> tuple[Any, int]:
    """Restore into the structure of ``like``.  Returns (tree, step)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves, treedef = jax.tree.flatten(like)
    restored = [_unpack_leaf(d) for d in payload["leaves"]]
    if len(restored) != len(leaves):
        raise ValueError(f"checkpoint has {len(restored)} leaves, "
                         f"expected {len(leaves)}")
    return jax.tree.unflatten(treedef, restored), payload["step"]


def latest(directory: str, prefix: str = "ckpt_") -> str | None:
    """Most recent step-tagged checkpoint in a directory."""
    if not os.path.isdir(directory):
        return None
    best, best_step = None, -1
    for name in os.listdir(directory):
        if name.startswith(prefix) and name.endswith(".msgpack"):
            try:
                step = int(name[len(prefix):-len(".msgpack")])
            except ValueError:
                continue
            if step > best_step:
                best, best_step = os.path.join(directory, name), step
    return best


def step_path(directory: str, step: int, prefix: str = "ckpt_") -> str:
    os.makedirs(directory, exist_ok=True)
    return os.path.join(directory, f"{prefix}{step:08d}.msgpack")
